"""Federated round planner: kernel/reference/bruteforce parity, pad
invariance, the deadline-gated simulator, serving integration (zero
post-warmup traces + metrics + cache isolation), the ``synth_population``
catalogue entry, and the PR's multi-device validation regressions."""
import dataclasses

import numpy as np
import pytest

from repro.core import (ErasureLink, GilbertElliottLink, IdealLink,
                        Scenario)
from repro.core.multidevice import (MultiDeviceSchedule, plan_multi_device,
                                    split_samples)
from repro.core.scenario import RidgeTask
from repro.data.synthetic import make_regression_dataset
from repro.federated import (FEDERATED_TOKEN, FederatedSimulator,
                             RoundPlanner, RoundRecord,
                             plan_round_bruteforce, plan_round_reference,
                             population_key)
from repro.fleet import PlanCache
from repro.fleet.tracing import trace_delta
from repro.serve import (FEDERATED_KIND, PlanningService, ServiceConfig,
                         default_consts, synth_population)

CONSTS = default_consts()
# the catalogue rate set: one padded rate width -> one kernel shape
RATES = (1.0, 1.25, 1.5, 2.0, 3.0)
GRID = 8


def _population(seed=0, size=6):
    """Small mixed-link population with a shared feasible-ish deadline."""
    rng = np.random.default_rng(seed)
    deadline = None
    pop = []
    for i in range(size):
        n = int(rng.integers(64, 2048))
        link = [
            IdealLink(rates=RATES),
            ErasureLink(beta=float(rng.uniform(0.0, 1.0)),
                        p_base=float(rng.uniform(0.0, 0.5)), rates=RATES),
            GilbertElliottLink(p_gb=float(rng.uniform(0.05, 0.8)),
                               p_bg=float(rng.uniform(0.05, 0.8)),
                               p_good=float(rng.uniform(0.0, 0.3)),
                               p_bad=float(rng.uniform(0.2, 0.9)),
                               beta=float(rng.uniform(0.0, 1.0)),
                               rates=RATES),
        ][i % 3]
        pop.append(Scenario(N=n, T=float(rng.uniform(0.8, 2.5)) * n,
                            n_o=float(rng.uniform(1.0, 800.0)),
                            tau_p=float(rng.choice([0.5, 1.0, 2.0])),
                            link=link))
    deadline = 1.4 * float(np.median([sc.N for sc in pop]))
    return pop, deadline


# ---------------------------------------------------------------------------
# planner == numpy reference == brute force
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [1, 2, 3, 4])
def test_plan_round_matches_reference_and_bruteforce(seed):
    pop, deadline = _population(seed)
    planner = RoundPlanner(grid_size=GRID)
    plan = planner.plan_round(pop, CONSTS, deadline=deadline, pad_to=8)
    ref = plan_round_reference(pop, CONSTS, deadline=deadline,
                               grid_size=GRID)

    assert np.array_equal(plan.participants, ref.participants)
    assert plan.k_best == ref.k_best
    assert plan.n_eligible == ref.n_eligible
    assert np.array_equal(plan.eligible, ref.eligible)
    assert np.array_equal(plan.n_c, ref.n_c)
    assert np.array_equal(plan.rate, ref.rate)

    if plan.feasible:
        bf = plan_round_bruteforce(pop, CONSTS, deadline=deadline,
                                   grid_size=GRID)
        rec = plan.record()
        assert rec.participants == bf.participants
        assert rec.n_c == bf.n_c
        assert rec.rate == bf.rate
        assert np.isclose(rec.objective_value, bf.objective_value,
                          rtol=1e-12)
        assert np.isclose(rec.round_time, bf.round_time, rtol=1e-12)


def test_plan_round_pad_invariance():
    """Pad lanes (valid=False) must not change the chosen round."""
    pop, deadline = _population(7, size=5)
    planner = RoundPlanner(grid_size=GRID)
    base = planner.plan_round(pop, CONSTS, deadline=deadline)   # pow2 -> 8
    padded = planner.plan_round(pop, CONSTS, deadline=deadline, pad_to=16)
    assert np.array_equal(base.participants, padded.participants)
    assert base.k_best == padded.k_best
    assert base.n_eligible == padded.n_eligible
    assert np.array_equal(base.n_c, padded.n_c)
    assert np.array_equal(base.rate, padded.rate)
    assert len(base) == len(padded) == 5


def test_plan_round_infeasible_population():
    pop, _ = _population(9, size=4)
    planner = RoundPlanner(grid_size=GRID)
    plan = planner.plan_round(pop, CONSTS, deadline=1e-3, pad_to=8)
    assert not plan.feasible
    assert plan.k_best == 0 and plan.n_eligible == 0
    assert plan.participants.size == 0
    assert plan.objective_value == np.inf
    assert plan.round_time == np.inf
    rec = plan.record()
    assert rec.participants == () and not rec.feasible
    assert rec.n_c == () and rec.rate == ()


def test_plan_round_validation():
    pop, deadline = _population(0, size=3)
    planner = RoundPlanner(grid_size=GRID)
    with pytest.raises(ValueError, match="non-empty"):
        planner.plan_round([], CONSTS)
    with pytest.raises(ValueError, match="deadline"):
        planner.plan_round(pop, CONSTS, deadline=0.0)
    from repro.fleet.batch import ScenarioBatch
    batch = ScenarioBatch.from_scenarios(pop)
    with pytest.raises(ValueError, match="n_real"):
        planner.plan_round_batch(batch, CONSTS, deadline=deadline,
                                 n_real=4)
    with pytest.raises(ValueError, match="grid"):
        planner.plan_round_batch(batch, CONSTS, deadline=deadline,
                                 grid=np.ones((5, GRID), np.int64))


def test_warm_then_plan_pays_zero_traces():
    pop, deadline = _population(3)
    planner = RoundPlanner(grid_size=GRID)
    planner.warm(pop, CONSTS, pad_to=8)
    with trace_delta() as traces:
        planner.plan_round(pop, CONSTS, deadline=deadline, pad_to=8)
        planner.plan_round(pop[:4], CONSTS, deadline=deadline, pad_to=8)
    assert traces.total == 0


# hypothesis sweep: randomly drawn mixed-link populations ------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @st.composite
    def _h_scenario(draw):
        kind = draw(st.sampled_from(["ideal", "erasure", "ge"]))
        if kind == "erasure":
            link = ErasureLink(beta=draw(st.floats(0.0, 1.5)),
                               p_base=draw(st.floats(0.0, 0.8)),
                               rates=RATES)
        elif kind == "ge":
            link = GilbertElliottLink(
                p_gb=draw(st.floats(0.01, 1.0)),
                p_bg=draw(st.floats(0.01, 1.0)),
                p_good=draw(st.floats(0.0, 0.8)),
                p_bad=draw(st.floats(0.0, 0.9)),
                beta=draw(st.floats(0.0, 1.5)), rates=RATES)
        else:
            link = IdealLink(rates=RATES)
        N = draw(st.integers(32, 4096))
        return Scenario(N=N, T=draw(st.floats(0.4, 3.0)) * N,
                        n_o=draw(st.floats(0.0, 1500.0)),
                        tau_p=draw(st.sampled_from([0.5, 1.0, 2.0])),
                        link=link)

    @settings(max_examples=15, deadline=None)
    @given(pop=st.lists(_h_scenario(), min_size=2, max_size=8),
           frac=st.floats(0.2, 2.5))
    def test_plan_round_property_matches_references(pop, frac):
        """ISSUE acceptance: participant set + per-participant (rate,
        n_c) argmin-identical to the numpy reference AND the exponential
        brute force on randomly drawn mixed-link populations."""
        deadline = frac * float(np.median([sc.N for sc in pop]))
        planner = RoundPlanner(grid_size=GRID)
        plan = planner.plan_round(pop, CONSTS, deadline=deadline,
                                  pad_to=8)       # one compiled shape
        ref = plan_round_reference(pop, CONSTS, deadline=deadline,
                                   grid_size=GRID)
        assert np.array_equal(plan.participants, ref.participants)
        assert plan.k_best == ref.k_best
        assert np.array_equal(plan.n_c, ref.n_c)
        assert np.array_equal(plan.rate, ref.rate)
        bf = plan_round_bruteforce(pop, CONSTS, deadline=deadline,
                                   grid_size=GRID)
        rec = plan.record()
        assert rec.participants == bf.participants
        assert rec.n_c == bf.n_c and rec.rate == bf.rate


# ---------------------------------------------------------------------------
# FederatedSimulator: sharded local SGD + deadline-gated averaging
# ---------------------------------------------------------------------------


def _feasible_plan(seed=1):
    for s in range(seed, seed + 20):
        pop, deadline = _population(s)
        plan = RoundPlanner(grid_size=GRID).plan_round(
            pop, CONSTS, deadline=deadline, pad_to=8)
        if plan.feasible:
            return pop, plan
    raise RuntimeError("no feasible population found")  # pragma: no cover


def test_simulator_runs_planned_round():
    pop, plan = _feasible_plan()
    X, y, _ = make_regression_dataset(n=256, d=6, seed=0)
    report = FederatedSimulator().run_round(pop, plan, RidgeTask(X=X, y=y))
    assert len(report.participants) == plan.k_best
    devs = sorted(r.device for r in report.participants)
    assert devs == list(plan.participants)
    # shards partition the task dataset remainder-exactly
    assert sum(r.shard_size for r in report.participants) == 256
    assert report.n_completed >= 1
    assert np.isfinite(report.aggregated_loss)
    assert report.w_round is not None and report.w_round.shape == (6,)
    assert 0.0 < report.completion_rate <= 1.0


def test_simulator_deadline_gates_stragglers():
    """Crushing the deadline after planning drops every participant."""
    pop, plan = _feasible_plan()
    starved = dataclasses.replace(plan, deadline=1e-6)
    X, y, _ = make_regression_dataset(n=128, d=4, seed=1)
    report = FederatedSimulator().run_round(pop, starved,
                                            RidgeTask(X=X, y=y))
    assert report.n_completed == 0
    assert report.aggregated_loss == np.inf
    assert report.w_round is None
    assert all(not r.completed for r in report.participants)


def test_simulator_infeasible_plan_and_length_mismatch():
    pop, _ = _population(9, size=4)
    plan = RoundPlanner(grid_size=GRID).plan_round(pop, CONSTS,
                                                   deadline=1e-3, pad_to=8)
    X, y, _ = make_regression_dataset(n=64, d=4, seed=2)
    report = FederatedSimulator().run_round(pop, plan, RidgeTask(X=X, y=y))
    assert report.participants == () and report.aggregated_loss == np.inf
    with pytest.raises(ValueError, match="population"):
        FederatedSimulator().run_round(pop[:2], plan, RidgeTask(X=X, y=y))


# ---------------------------------------------------------------------------
# serving integration
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def round_service():
    service = PlanningService(ServiceConfig(
        grid_size=GRID, batch_buckets=(4,), grid_modes=("dense",),
        objective_ids=("corollary1",), population_buckets=(8,),
        n_max=512, shard=False))
    service.warmup()
    yield service


def test_submit_round_zero_traces_metrics_and_cache(round_service):
    service = round_service
    pop, deadline = synth_population(6, seed=4, n_max=512)
    with trace_delta() as traces:
        record = service.submit_round(pop, deadline=deadline)
        repeat = service.submit_round(pop, deadline=deadline)
    assert traces.total == 0
    assert repeat == record                       # cache hit, same object
    assert isinstance(record, RoundRecord)
    stats = service.cache.stats()
    assert stats["hits_by_objective"].get(FEDERATED_KIND, 0) >= 1

    metrics = service.metrics_snapshot()
    assert int(metrics["repro_serve_post_warmup_traces_total"][()]) == 0
    assert int(metrics["repro_federated_rounds_total"][()]) >= 2
    if record.feasible:
        assert int(metrics["repro_federated_participants_total"][()]) >= \
            2 * record.n_participants
    # the plan agrees with a direct planner call at the serving pad shape
    direct = service.round_planner.plan_round(
        pop, service.consts, deadline=deadline, pad_to=8).record()
    assert direct == record


def test_federated_cache_key_isolated_from_scenario_plans(round_service):
    """Satellite: a federated entry can never alias a per-scenario plan
    even when the round is a single-device population."""
    service = round_service
    pop, deadline = synth_population(1, seed=6, n_max=512)
    cache = PlanCache(maxsize=32)
    key = (service.round_planner.cache_context(service.consts),
           FEDERATED_TOKEN, population_key(pop, deadline))
    cache.put_by_key(key, "round-entry")
    # the same scenario stored through the scenario path
    cache.put(pop[0], "scenario-entry",
              context=("federated", service.consts,
                       service.round_planner.grid_size))
    assert len(cache) == 2                        # no aliasing
    assert cache.get_by_key(key, label=FEDERATED_KIND) == "round-entry"
    assert cache.get(pop[0],
                     context=("federated", service.consts,
                              service.round_planner.grid_size)) == \
        "scenario-entry"
    stats = cache.stats()
    assert stats["hits_by_objective"][FEDERATED_KIND] == 1
    # population keys quantise the deadline like scenario keys do
    assert population_key(pop, deadline) == \
        population_key(pop, deadline * (1 + 1e-9))
    assert population_key(pop, deadline) != \
        population_key(pop, deadline * 2)


def test_population_buckets_config_validation():
    with pytest.raises(ValueError, match="powers of two"):
        ServiceConfig(population_buckets=(3,))
    with pytest.raises(ValueError, match="ascend"):
        ServiceConfig(population_buckets=(16, 8))


def test_synth_population_deterministic_and_validated():
    a, da = synth_population(5, seed=3, n_max=512)
    b, db = synth_population(5, seed=3, n_max=512)
    assert da == db and a == b
    c, _ = synth_population(5, seed=4, n_max=512)
    assert c != a
    assert all(sc.T == da for sc in a)            # shared round deadline
    with pytest.raises(ValueError, match="unknown link model"):
        synth_population(2, models=("nope",))
    with pytest.raises(ValueError):
        synth_population(0)


def test_federated_cli_verify_and_errors(tmp_path):
    from repro.launch.federated import main
    metrics = tmp_path / "fed.prom"
    assert main(["--devices", "5", "--rounds", "1", "--pop-buckets", "8",
                 "--grid", str(GRID), "--n-max", "512", "--verify",
                 "--metrics-textfile", str(metrics)]) == 0
    text = metrics.read_text()
    assert "repro_federated_rounds_total" in text
    assert main(["--models", "nope"]) == 2
    assert main(["--pop-buckets", "x"]) == 2


# ---------------------------------------------------------------------------
# multi-device validation + remainder-exact sharding (satellite)
# ---------------------------------------------------------------------------


def test_split_samples_remainder_exact():
    assert split_samples(1003, 4) == (251, 251, 251, 250)
    assert split_samples(8, 3) == (3, 3, 2)
    assert split_samples(5, 5) == (1, 1, 1, 1, 1)
    with pytest.raises(ValueError):
        split_samples(4, 0)
    with pytest.raises(ValueError):
        split_samples(2, 3)                       # device with no samples


def test_multi_device_schedule_validates_inputs():
    ok = dict(n_devices=2, samples_per_device=4, n_c=2, n_o=1.0,
              T=100.0, tau_p=1.0)
    MultiDeviceSchedule(**ok)                     # sanity: valid baseline
    for bad in [dict(ok, n_devices=0), dict(ok, samples_per_device=0),
                dict(ok, n_c=0), dict(ok, n_o=-1.0), dict(ok, T=0.0),
                dict(ok, tau_p=0.0)]:
        with pytest.raises(ValueError):
            MultiDeviceSchedule(**bad)
    with pytest.raises(ValueError, match="shard sizes"):
        MultiDeviceSchedule(**ok, shard_sizes=(4,))        # wrong length
    with pytest.raises(ValueError, match="at least one sample"):
        MultiDeviceSchedule(**ok, shard_sizes=(4, 0))      # empty shard
    with pytest.raises(ValueError, match="samples_per_device"):
        MultiDeviceSchedule(**ok, shard_sizes=(3, 3))      # max != spd


def test_multi_device_uneven_shards_available_at():
    sched = MultiDeviceSchedule(n_devices=3, samples_per_device=3, n_c=2,
                                n_o=1.0, T=100.0, tau_p=1.0,
                                shard_sizes=(3, 3, 2))
    assert sched.N_total == 8
    # one TDMA cycle (3 slots of n_c + n_o = 3): every device shipped one
    # block of min(n_c, shard) samples
    assert sched.available_at(9.0) == 2 + 2 + 2
    # by the deadline the short shard contributes only its own 2 samples
    assert sched.available_at(sched.T) == 8


def test_plan_multi_device_total_N_path():
    res = plan_multi_device(n_devices=4, N=1003, T=4000.0, n_o=8.0,
                            tau_p=1.0, consts=CONSTS)
    assert res["shard_sizes"] == (251, 251, 251, 250)
    assert sum(res["shard_sizes"]) == 1003
    assert res["schedule"].N_total == 1003
    legacy = plan_multi_device(n_devices=4, samples_per_device=251,
                               T=4000.0, n_o=8.0, tau_p=1.0, consts=CONSTS)
    assert legacy["shard_sizes"] == (251, 251, 251, 251)
    with pytest.raises(ValueError, match="exactly one"):
        plan_multi_device(n_devices=4, T=4000.0, n_o=8.0, tau_p=1.0,
                          consts=CONSTS)
    with pytest.raises(ValueError, match="exactly one"):
        plan_multi_device(n_devices=4, samples_per_device=8, N=32,
                          T=4000.0, n_o=8.0, tau_p=1.0, consts=CONSTS)
