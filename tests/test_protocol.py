"""Protocol arithmetic (paper Sec. 2, Fig. 2)."""
import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.protocol import BlockSchedule, boundary_n_c


def test_paper_fig3_regime_examples():
    # paper setting: N = 18576, T = 1.5 N
    N, T = 18_576, 1.5 * 18_576
    # small n_c, small overhead -> whole dataset delivered before T
    s = BlockSchedule(N=N, n_c=100, n_o=10.0, T=T, tau_p=1.0)
    assert s.full_transfer
    assert s.delivered_fraction == 1.0
    assert s.tau_l > 0 and s.n_l == int(s.tau_l)
    # huge overhead -> only part of the data arrives
    s = BlockSchedule(N=N, n_c=100, n_o=5000.0, T=T, tau_p=1.0)
    assert not s.full_transfer
    assert s.delivered_fraction < 1.0


def test_boundary_matches_regime_flip():
    N, T, n_o = 10_000, 15_000.0, 200.0
    b = boundary_n_c(N, T, n_o)
    # +-20% margin: the analytic boundary uses the paper's continuous
    # B_d = N/n_c; the simulation delivers in whole blocks (ceil semantics)
    below = BlockSchedule(N=N, n_c=int(b * 0.8), n_o=n_o, T=T, tau_p=1.0)
    above = BlockSchedule(N=N, n_c=int(b * 1.2), n_o=n_o, T=T, tau_p=1.0)
    # larger blocks amortise overhead: above the boundary the whole set fits
    assert above.full_transfer
    assert not below.full_transfer


def test_boundary_infinite_when_T_leq_N():
    assert math.isinf(boundary_n_c(1000, 900.0, 10.0))


def test_available_at_block_ends():
    s = BlockSchedule(N=1000, n_c=100, n_o=10.0, T=2000.0, tau_p=1.0)
    assert s.available_at(0.0) == 0
    assert s.available_at(109.9) == 0          # block 1 still in flight
    assert s.available_at(110.0) == 100        # block 1 delivered
    assert s.available_at(220.0) == 200
    assert s.available_at(1e9) == 1000         # capped at N


def test_updates_timeline_monotone():
    s = BlockSchedule(N=1000, n_c=64, n_o=16.0, T=3000.0, tau_p=1.0)
    tl = s.updates_timeline()
    assert len(tl) == s.total_updates
    assert (np.diff(tl) >= 0).all()
    assert tl.max() <= 1000


@settings(max_examples=200, deadline=None)
@given(
    n=st.integers(100, 50_000),
    n_c=st.integers(1, 5_000),
    n_o=st.floats(0.0, 1_000.0),
    t_factor=st.floats(0.1, 3.0),
    tau_p=st.floats(0.25, 4.0),
)
def test_protocol_invariants(n, n_c, n_o, t_factor, tau_p):
    n_c = min(n_c, n)
    s = BlockSchedule(N=n, n_c=n_c, n_o=n_o, T=t_factor * n, tau_p=tau_p)
    assert 0.0 <= s.delivered_fraction <= 1.0
    assert s.n_p >= 0 and s.n_l >= 0
    assert s.available_at(s.T) <= n
    # full_transfer <=> the protocol delivers everything strictly before T
    if s.full_transfer:
        assert s.available_at(s.T) == n
    # updates never exceed the time budget
    assert s.total_updates * s.tau_p <= s.T + 1e-9
