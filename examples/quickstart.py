"""Quickstart: the paper's pipelined edge-learning protocol in ~40 lines,
through the unified three-object API:

    Scenario  — what the system looks like (N, T, n_o, tau_p, link, topology)
    Planner   — how to pick the block size (here: the Corollary-1 bound)
    Simulator — run the workload under the planned schedule

A device holds N samples and must offload them to an edge learner within a
deadline T.  We (1) describe the system as a Scenario, (2) pick the block
size n_c by minimising the Corollary-1 bound, (3) run the pipelined
streaming-SGD trainer, and (4) compare against the transmit-everything-first
baseline.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (BoundConstants, BoundPlanner, RidgeTask, Scenario,
                        Simulator)
from repro.data import make_regression_dataset

# the paper's Sec.-5 setting (California-Housing-like synthetic)
X, y, _ = make_regression_dataset()
N = len(X)

# 1) describe the system: deadline 1.5x the full-transfer time, 500-sample
#    packet overhead, ideal link, single device (the defaults)
scenario = Scenario(N=N, T=1.5 * N, n_o=500.0)

# 2) plan the block size from the bound — no Monte-Carlo needed
consts = BoundConstants(L=1.908, c=0.061, M=1.0, M_G=1.0, D=6.0, alpha=1e-4)
plan = BoundPlanner().plan(scenario, consts)
print(f"bound-optimal block size: n_c = {plan.n_c} "
      f"(regime boundary at {plan.boundary:.0f}, "
      f"full transfer: {plan.full_transfer})")

# 3) train under the pipelined protocol
sim = Simulator()
task = RidgeTask(X=X, y=y)
piped = sim.run(scenario, plan, task)
print(f"pipelined   (n_c={plan.n_c:6d}): final loss {piped.final_loss:.4f}, "
      f"{piped.delivered}/{N} samples delivered")

# 4) the baseline the paper argues against: send everything, then train
seq_plan = BoundPlanner(grid=[N]).plan(scenario, consts)
seq = sim.run(scenario, seq_plan, task)
print(f"sequential  (n_c={N:6d}): final loss {seq.final_loss:.4f}")
print(f"pipelining improves the final training loss by "
      f"{(seq.final_loss - piped.final_loss) / seq.final_loss * 100:.1f}%")
