"""Quickstart: the paper's pipelined edge-learning protocol in ~40 lines.

A device holds N samples and must offload them to an edge learner within a
deadline T.  We (1) pick the block size n_c by minimising the Corollary-1
bound, (2) run the pipelined streaming-SGD trainer, and (3) compare against
the transmit-everything-first baseline.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import BoundConstants, optimize_block_size, run_pipelined_sgd
from repro.data import make_regression_dataset

# the paper's Sec.-5 setting (California-Housing-like synthetic; see DESIGN.md)
X, y, _ = make_regression_dataset()
N = len(X)
T = 1.5 * N          # deadline: 1.5x the time to transmit the whole set
n_o = 500.0          # per-packet overhead (pilots / meta-data)

# 1) plan the block size from the bound — no Monte-Carlo needed
consts = BoundConstants(L=1.908, c=0.061, M=1.0, M_G=1.0, D=6.0, alpha=1e-4)
plan = optimize_block_size(N=N, T=T, n_o=n_o, tau_p=1.0, consts=consts)
print(f"bound-optimal block size: n_c = {plan.n_c} "
      f"(regime boundary at {plan.boundary:.0f}, "
      f"full transfer: {plan.full_transfer})")

# 2) train under the pipelined protocol
piped = run_pipelined_sgd(X, y, n_c=plan.n_c, n_o=n_o, T=T)
print(f"pipelined   (n_c={plan.n_c:6d}): final loss {piped.final_loss:.4f}, "
      f"{piped.delivered}/{N} samples delivered")

# 3) the baseline the paper argues against: send everything, then train
seq = run_pipelined_sgd(X, y, n_c=N, n_o=n_o, T=T)
print(f"sequential  (n_c={N:6d}): final loss {seq.final_loss:.4f}")
print(f"pipelining improves the final training loss by "
      f"{(seq.final_loss - piped.final_loss) / seq.final_loss * 100:.1f}%")
