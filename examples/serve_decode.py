"""Serve a small model with batched requests: prefill a batch of prompts
token-by-token into the KV cache, then decode greedily — exercising the
same serve_step the decode_32k / long_500k dry-run shapes lower.

    PYTHONPATH=src python examples/serve_decode.py --arch mixtral-8x7b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.launch.serve_decode import greedy_generate
from repro.models import init_params

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="llama3.2-1b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=24)
ap.add_argument("--gen", type=int, default=12)
args = ap.parse_args()

cfg = reduced(get_config(args.arch))
print(f"serving {cfg.name}: batch={args.batch}, "
      f"prompt={args.prompt_len}, generate={args.gen}")
params = init_params(cfg, 0)
prompts = jax.random.randint(jax.random.PRNGKey(1),
                             (args.batch, args.prompt_len), 0,
                             cfg.vocab_size, jnp.int32)
t0 = time.time()
out = greedy_generate(cfg, params, prompts, args.gen,
                      max_len=args.prompt_len + args.gen)
dt = time.time() - t0
print(f"generated {args.batch}x{args.gen} tokens in {dt:.1f}s")
for i, row in enumerate(out.tolist()):
    print(f"  request {i}: {row}")
