"""The paper's full Sec.-5 experiment: Fig. 3 (bound vs block size for
several overheads) and Fig. 4 (training loss vs time at the experimental
and bound optima), printed as text tables.

    PYTHONPATH=src python examples/edge_ridge_regression.py
"""
import numpy as np

from repro.configs.edge_ridge import EDGE_RIDGE_PARAMS as EP
from repro.core import (BoundConstants, BoundPlanner, Scenario,
                        average_final_loss, run_pipelined_sgd)
from repro.data import make_regression_dataset

X, y, _ = make_regression_dataset(n=EP.n_samples, d=EP.n_features)
N, T = EP.n_samples, EP.T_factor * EP.n_samples
consts = BoundConstants(L=EP.L, c=EP.c, M=EP.M, M_G=EP.M_G, D=1.0,
                        alpha=EP.alpha)
planner = BoundPlanner()

print("== Fig. 3: Corollary-1 bound vs n_c ==")
print(f"{'n_o':>6} | {'n_c~ (bound opt)':>16} | {'boundary':>9} | full transfer at opt?")
for n_o in (10.0, 100.0, 1000.0, 5000.0):
    plan = planner.plan(Scenario(N=N, T=T, n_o=n_o), consts)
    print(f"{n_o:6.0f} | {plan.n_c:16d} | {plan.boundary:9.0f} | {plan.full_transfer}")

print("\n== Fig. 4: loss vs time at n_o = 1000 ==")
n_o = 1000.0
for n_c in (128, 1024, 4675, N):
    r = run_pipelined_sgd(X, y, n_c=n_c, n_o=n_o, T=T, alpha=EP.alpha,
                          lam=EP.lam, record_every=4096)
    marks = "  ".join(f"t={t:6.0f}:{l:7.4f}" for t, l in
                      zip(r.trace_times[::2], r.loss_trace[::2]))
    print(f"n_c={n_c:6d}: {marks}  final={r.final_loss:.4f}")

print("\n== experimental vs bound optimum (the paper's 3.8% claim) ==")
grid = [64, 256, 1024, 4096, N]
losses = {nc: average_final_loss(X, y, n_c=nc, n_o=n_o, T=T, n_runs=2,
                                 alpha=EP.alpha, lam=EP.lam) for nc in grid}
star = min(losses, key=losses.get)
plan = BoundPlanner(grid=grid).plan(Scenario(N=N, T=T, n_o=n_o), consts)
gap = (losses[plan.n_c] - losses[star]) / losses[star] * 100
print(f"experimental optimum n_c* = {star}; bound optimum n_c~ = {plan.n_c}; "
      f"loss gap = {gap:.1f}%")
