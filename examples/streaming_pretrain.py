"""End-to-end driver: pretrain a (reduced) llama3-family model for a few
hundred steps under the paper's streaming schedule — the 'sample' is a
packed sequence, blocks of sequences arrive on the Fig.-2 timeline, and
every tau_p the mesh takes one AdamW step on the delivered prefix.

    PYTHONPATH=src python examples/streaming_pretrain.py [--steps 300]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import (BoundConstants, BoundPlanner, Scenario, Simulator,
                        StreamingTask)
from repro.data.synthetic import SyntheticTokens
from repro.models import init_params, make_train_step
from repro.optim import linear_warmup_cosine
from repro.optim.optimizers import make_optimizer

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--arch", default="llama3.2-1b")
args = ap.parse_args()

cfg = reduced(get_config(args.arch))
n_seqs, seq_len, batch = 512, 128, 8
print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f}M params), "
      f"{args.steps} updates, {n_seqs} sequences streaming in")

data = SyntheticTokens(cfg.vocab_size, seq_len, n_seqs, seed=0).batch(0)
params = init_params(cfg, 0)
opt = make_optimizer("adamw", linear_warmup_cosine(1e-3, 20, args.steps))
train_step = jax.jit(make_train_step(cfg, opt), donate_argnums=(0, 1))

# plan the block size with the paper's bound (constants are heuristic for a
# non-convex learner); the Scenario -> Planner -> Simulator triple wraps the
# generic streaming trainer exactly like the ridge task
scenario = Scenario(N=n_seqs, T=float(args.steps), n_o=16.0, tau_p=1.0)
consts = BoundConstants(L=1.0, c=0.05, M=1.0, M_G=1.0, D=2.0, alpha=1e-3)
plan = BoundPlanner().plan(scenario, consts)
sched = plan.schedule
print(f"planner: n_c = {plan.n_c} sequences/block, {sched.n_p} updates/block, "
      f"full transfer: {plan.full_transfer}")

report = Simulator().run(scenario, plan, StreamingTask(
    train_step=train_step, params=params, opt_state=opt.init(params),
    dataset=np.asarray(data), batch_size=batch,
    make_batch=lambda tok: {"tokens": jnp.asarray(tok)}, log_every=20))
state = report.state

for h in state.history:
    print(f"update {h['update']:4d}: {h['available']:4d}/{n_seqs} seqs "
          f"available, loss {h['loss']:.4f}")
print(f"done: {state.delivered}/{n_seqs} delivered, "
      f"final loss {state.history[-1]['loss']:.4f}")
