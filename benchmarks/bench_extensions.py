"""Sec.-6 extensions benchmark: (a) Theorem-1 Monte-Carlo vs Corollary-1
looseness, (b) joint (n_c, rate) planning on an erasure channel — timing
the vectorised broadcast sweep against the seed per-grid-point Python
loop, (c) multi-device TDMA reduction, (d) the erasure x multi-device
cross product through the unified Scenario/Planner API."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_artifact
from repro.configs.edge_ridge import EDGE_RIDGE_PARAMS as EP
from repro.core import (BoundConstants, BoundPlanner, ErasureLink,
                        MultiDevice, Scenario)
from repro.core.bounds import corollary1_bound
from repro.core.channel import ErasureChannel, plan_with_channel
from repro.core.montecarlo import estimate_theorem1
from repro.core.multidevice import plan_multi_device
from repro.core.planner import default_grid
from repro.data.synthetic import make_regression_dataset

RATES = (1.0, 1.25, 1.5, 2.0, 3.0)


def _plan_with_channel_loop(*, N, T, n_o, tau_p, consts, channel,
                            rates=RATES, grid=None):
    """The seed implementation: one corollary1_bound call per grid point
    (kept verbatim as the timing baseline for the vectorised sweep)."""
    grid = np.asarray(grid if grid is not None else default_grid(N))
    best = None
    for rate in rates:
        p = channel.p_err(rate)
        dur = (grid / rate + n_o) / (1.0 - p)
        n_o_eff = dur - grid
        vals = np.array([
            corollary1_bound(np.asarray([nc]), N=N, T=T, n_o=float(no),
                             tau_p=tau_p, consts=consts)[0]
            for nc, no in zip(grid, n_o_eff)
        ])
        i = int(np.argmin(vals))
        cand = (float(vals[i]), int(grid[i]), float(rate), float(p))
        if best is None or cand[0] < best[0]:
            best = cand
    bound_val, n_c, rate, p = best
    return {"n_c": n_c, "rate": rate, "p_err": p, "bound": bound_val}


def run():
    t0 = time.perf_counter()

    # (a) Theorem 1 vs Corollary 1
    X, y, _ = make_regression_dataset(n=4096, d=8, seed=5)
    consts = BoundConstants(L=EP.L, c=EP.c, M=1.0, M_G=1.0, D=4.0, alpha=1e-3)
    mc = estimate_theorem1(X, y, n_c=256, n_o=100.0, T=1.5 * 4096,
                           consts=consts, alpha=1e-3, n_runs=3)

    # (b) erasure channel with rate selection: vectorised vs seed loop
    chan_consts = BoundConstants(L=EP.L, c=EP.c, M=1.0, M_G=1.0, D=1.0,
                                 alpha=EP.alpha)
    kw = dict(N=EP.n_samples, T=1.5 * EP.n_samples, n_o=500.0, tau_p=1.0,
              consts=chan_consts)
    plans = {}
    t_vec = t_loop = 0.0
    for beta in (0.1, 0.4, 1.0):
        channel = ErasureChannel(beta=beta)
        t1 = time.perf_counter()
        plans[beta] = plan_with_channel(channel=channel, **kw)
        t_vec += time.perf_counter() - t1
        t1 = time.perf_counter()
        ref = _plan_with_channel_loop(channel=channel, **kw)
        t_loop += time.perf_counter() - t1
        # n_c / rate must agree exactly; bound / p_err only to rounding
        # (ErasureLink uses np.exp, the seed channel math.exp — those can
        # differ by an ulp depending on the libm build)
        assert plans[beta]["n_c"] == ref["n_c"], (plans[beta], ref)
        assert plans[beta]["rate"] == ref["rate"], (plans[beta], ref)
        for k in ("bound", "p_err"):
            assert np.isclose(plans[beta][k], ref[k], rtol=1e-12, atol=0.0), \
                (plans[beta], ref)
    speedup = t_loop / t_vec

    # (c) multi-device
    md = plan_multi_device(n_devices=4, samples_per_device=EP.n_samples // 4,
                           T=1.5 * EP.n_samples, n_o=100.0, tau_p=1.0,
                           consts=chan_consts)

    # (d) the cross product only the unified API can express
    cross = BoundPlanner().plan(
        Scenario(N=EP.n_samples, T=1.5 * EP.n_samples, n_o=100.0,
                 link=ErasureLink(beta=0.4), topology=MultiDevice(4)),
        chan_consts)

    dt_us = (time.perf_counter() - t0) * 1e6
    save_artifact("extensions", {
        "theorem1_vs_corollary1": mc,
        "channel_plans": {str(k): v for k, v in plans.items()},
        "joint_sweep_vectorised_s": t_vec,
        "joint_sweep_loop_s": t_loop,
        "joint_sweep_speedup": speedup,
        "multi_device": {k: v for k, v in md.items() if k != "schedule"},
        "erasure_x_multidevice": {
            "n_c": cross.n_c, "n_c_per_device": cross.n_c_per_device,
            "rate": cross.rate, "bound": cross.bound_value},
    })
    emit("extensions_sec6", dt_us,
         f"Th1={mc['theorem1']:.4f} Cor1={mc['corollary1']:.4f} "
         f"looseness={mc['looseness_c1_over_th1']:.2f}x "
         f"rate_choice_by_beta={[plans[b]['rate'] for b in (0.1, 0.4, 1.0)]} "
         f"joint_sweep_speedup={speedup:.0f}x "
         f"multidev_nc_per_dev={md['n_c_per_device']}")
    assert speedup >= 10.0, (
        f"vectorised joint (n_c, rate) sweep only {speedup:.1f}x faster "
        "than the per-point loop")
    return mc, plans, md


if __name__ == "__main__":
    run()
