"""Sec.-6 extensions benchmark: (a) Theorem-1 Monte-Carlo vs Corollary-1
looseness, (b) joint (n_c, rate) planning on an erasure channel,
(c) multi-device TDMA reduction."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_artifact
from repro.configs.edge_ridge import EDGE_RIDGE_PARAMS as EP
from repro.core.bounds import BoundConstants
from repro.core.channel import ErasureChannel, plan_with_channel
from repro.core.montecarlo import estimate_theorem1
from repro.core.multidevice import plan_multi_device
from repro.data.synthetic import make_regression_dataset


def run():
    t0 = time.perf_counter()

    # (a) Theorem 1 vs Corollary 1
    X, y, _ = make_regression_dataset(n=4096, d=8, seed=5)
    consts = BoundConstants(L=EP.L, c=EP.c, M=1.0, M_G=1.0, D=4.0, alpha=1e-3)
    mc = estimate_theorem1(X, y, n_c=256, n_o=100.0, T=1.5 * 4096,
                           consts=consts, alpha=1e-3, n_runs=3)

    # (b) erasure channel with rate selection
    chan_consts = BoundConstants(L=EP.L, c=EP.c, M=1.0, M_G=1.0, D=1.0,
                                 alpha=EP.alpha)
    plans = {}
    for beta in (0.1, 0.4, 1.0):
        plans[beta] = plan_with_channel(
            N=EP.n_samples, T=1.5 * EP.n_samples, n_o=500.0, tau_p=1.0,
            consts=chan_consts, channel=ErasureChannel(beta=beta))

    # (c) multi-device
    md = plan_multi_device(n_devices=4, samples_per_device=EP.n_samples // 4,
                           T=1.5 * EP.n_samples, n_o=100.0, tau_p=1.0,
                           consts=chan_consts)

    dt_us = (time.perf_counter() - t0) * 1e6
    save_artifact("extensions", {
        "theorem1_vs_corollary1": mc,
        "channel_plans": {str(k): v for k, v in plans.items()},
        "multi_device": {k: v for k, v in md.items() if k != "schedule"},
    })
    emit("extensions_sec6", dt_us,
         f"Th1={mc['theorem1']:.4f} Cor1={mc['corollary1']:.4f} "
         f"looseness={mc['looseness_c1_over_th1']:.2f}x "
         f"rate_choice_by_beta={[plans[b]['rate'] for b in (0.1, 0.4, 1.0)]} "
         f"multidev_nc_per_dev={md['n_c_per_device']}")
    return mc, plans, md


if __name__ == "__main__":
    run()
