"""Federated round-planner benchmark: fan-in speedup, parity, zero traces.

Exercises :mod:`repro.federated` at fleet scale:

  1. **Speedup** — one jitted :meth:`RoundPlanner.plan_round_batch` call
     over a 1024-device population vs :func:`plan_round_reference`, the
     per-device scalar numpy planning loop (grid evaluation per device in
     Python) + host-side participation scan.  Asserts >= 20x — the
     acceptance floor for folding the participation axis into the
     batched kernel instead of looping the fleet.
  2. **Parity** — the jitted round's participant set and every
     participant's ``(rate, n_c)`` must equal the reference argmin
     exactly (the same tie-breaking contract the fleet planner's
     scalar-equivalence tests enforce, plus the participation axis).
  3. **Serving SLO** — a warmed :class:`PlanningService` plans rounds
     through ``submit_round`` with ZERO post-warmup jit traces, read
     through the unified metrics registry (``repro_federated_*``
     families render and parse on the way); a repeated round is a cache
     hit.
  4. **Artifact** — ``BENCH_federated.json`` at the repo root
     (provenance-stamped, schema v2), merged into the perf trajectory by
     ``make_report trajectory`` and uploaded by CI.

Standalone:  PYTHONPATH=src python -m benchmarks.bench_federated
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import bench_stamp, emit, save_artifact
from repro.core.planner import fleet_grid
from repro.federated import RoundPlanner, plan_round_reference
from repro.fleet.batch import ScenarioBatch
from repro.serve import (PlanningService, ServiceConfig, default_consts,
                         synth_population)

POPULATION = 1024
GRID_SIZE = 64
POP_BUCKETS = (64, 1024)
N_MAX = 4096
REPS = 15
#: acceptance floor: the jitted round solve vs the per-device scalar
#: planning loop at population >= 512
SPEEDUP_FLOOR = 20.0

#: perf-trajectory artifact written at the repo root
BENCH_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_federated.json")


def run():
    consts = default_consts()
    population, deadline = synth_population(POPULATION, seed=11,
                                            n_max=N_MAX)
    planner = RoundPlanner(grid_size=GRID_SIZE)

    # ---- jitted round solve (warm, then timed) -----------------------------
    # the prebuilt-batch contract bench_fleet times plan_batch under:
    # Scenario -> array conversion and the per-device grids are hoisted
    # out of the timed region on BOTH sides (the scalar loop reads the
    # Scenario objects directly and its per-device fleet_grid calls are
    # noise at this scale)
    batch = ScenarioBatch.from_scenarios(population)
    grid = fleet_grid(batch.N, GRID_SIZE)
    t0 = time.perf_counter()
    planner.warm(population, consts, pad_to=POPULATION)
    warm_s = time.perf_counter() - t0
    samples = []
    for _ in range(REPS):
        t0 = time.perf_counter()
        plan = planner.plan_round_batch(batch, consts, deadline=deadline,
                                        grid=grid)
        samples.append(time.perf_counter() - t0)
    # min over repeats: single-core boxes are noisy and the floor is
    # what the 20x assertion is calibrated against (bench_fleet's rule)
    jit_s = min(samples)
    emit("federated_round", jit_s * 1e6,
         f"S={POPULATION} G={GRID_SIZE} K={plan.k_best} "
         f"eligible={plan.n_eligible} warm={warm_s:.2f}s")
    t0 = time.perf_counter()
    full_plan = planner.plan_round(population, consts, deadline=deadline,
                                   pad_to=POPULATION)
    emit("federated_round_convert", (time.perf_counter() - t0) * 1e6,
         "plan_round incl. Scenario->batch conversion")
    assert np.array_equal(full_plan.participants, plan.participants)

    # ---- the per-device scalar planning loop (the baseline) ----------------
    t0 = time.perf_counter()
    ref = plan_round_reference(population, consts, deadline=deadline,
                               grid_size=GRID_SIZE)
    ref_s = time.perf_counter() - t0
    speedup = ref_s / jit_s
    emit("federated_scalar_loop", ref_s * 1e6,
         f"S={POPULATION} speedup={speedup:.1f}x")
    assert speedup >= SPEEDUP_FLOOR, (
        f"jitted round solve is only {speedup:.1f}x the per-device scalar "
        f"loop at S={POPULATION} (floor {SPEEDUP_FLOOR}x)")

    # ---- argmin parity vs the reference ------------------------------------
    assert np.array_equal(plan.participants, ref.participants), (
        f"participant sets differ: {plan.participants[:8]}... vs "
        f"{ref.participants[:8]}...")
    assert plan.k_best == ref.k_best
    assert plan.n_eligible == ref.n_eligible
    assert np.array_equal(plan.n_c, ref.n_c), "per-device n_c differ"
    assert np.array_equal(plan.rate, ref.rate), "per-device rates differ"
    # values may differ in the last ulp where the backend libm disagrees
    # (the bench_fleet rule: argmins exact, bounds within 1e-9 relative)
    finite = np.isfinite(ref.bound_value)
    assert np.array_equal(finite, np.isfinite(plan.bound_value))
    gap = np.abs(plan.bound_value[finite] - ref.bound_value[finite])
    assert np.all(gap <= 1e-9 * np.abs(ref.bound_value[finite])), (
        f"best-feasible bounds diverge beyond 1e-9 relative: "
        f"max gap {gap.max()}")

    # ---- serving path: zero post-warmup traces + cache hit -----------------
    config = ServiceConfig(grid_size=GRID_SIZE, batch_buckets=(8,),
                           grid_modes=("dense",),
                           objective_ids=("corollary1",),
                           population_buckets=POP_BUCKETS, n_max=N_MAX)
    service = PlanningService(config)
    warm_traces = service.warmup()
    t0 = time.perf_counter()
    record = service.submit_round(population, deadline=deadline)
    serve_s = time.perf_counter() - t0
    repeat = service.submit_round(population, deadline=deadline)
    assert repeat == record, "repeated round missed the cache"

    metrics = service.metrics_snapshot()
    post_traces = int(metrics["repro_serve_post_warmup_traces_total"][()])
    assert post_traces == 0, (
        f"{post_traces} jit trace(s) after warmup on the federated round "
        "path — the population-bucket sweep missed a shape")
    assert int(metrics["repro_federated_rounds_total"][()]) == 2
    assert int(metrics["repro_federated_participants_total"][()]) == \
        2 * record.n_participants
    cache = service.cache.stats()
    assert cache["hits_by_objective"].get("federated_round", 0) == 1, (
        f"expected 1 federated cache hit, got {cache['hits_by_objective']}")
    assert record.participants == tuple(int(i) for i in plan.participants)
    emit("federated_serve", serve_s * 1e6,
         f"K={record.n_participants} post_warm_traces={post_traces} "
         f"cache_hit=1")

    payload = {
        "bench": "federated",
        **bench_stamp(),
        "population": POPULATION, "grid_size": GRID_SIZE,
        "population_buckets": list(POP_BUCKETS),
        "deadline": deadline,
        "round_us": jit_s * 1e6,
        "scalar_loop_us": ref_s * 1e6,
        "speedup_vs_scalar": speedup,
        "rounds_per_sec": 1.0 / jit_s,
        "devices_per_sec": POPULATION / jit_s,
        "k_best": int(plan.k_best),
        "n_eligible": int(plan.n_eligible),
        "round_time": float(plan.round_time),
        "objective_value": float(plan.objective_value),
        "warmup_traces": warm_traces,
        "warmup_seconds": service.warmup_seconds,
        "post_warmup_traces": post_traces,
        "serve_round_us": serve_s * 1e6,
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    save_artifact("federated", payload)
    return speedup


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
