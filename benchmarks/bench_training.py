"""Paper Fig. 4 + the "3.8%" claim: train the ridge model under the
pipelined protocol for a grid of block sizes, find the experimental optimum
n_c*, and compare its final loss against the loss at the bound-optimised
n_c-tilde.  The paper reports the bound-driven choice gives up only ~3.8%
final training loss versus the (expensive) experimental search."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_artifact
from repro.configs.edge_ridge import EDGE_RIDGE_PARAMS as EP
from repro.core import (BoundConstants, average_final_loss,
                        optimize_block_size, run_pipelined_sgd)
from repro.data.synthetic import make_regression_dataset

N_C_GRID = [32, 64, 128, 256, 512, 1024, 2048, 4096, 9288, 18576]


def _calibrate_D(X, y, lam, seed=0):
    """D ~ 2 ||w0 - w*||: iterate diameter from init scale and the ridge
    solution (A1's W must contain the whole trajectory)."""
    n, d = X.shape
    w_star = np.linalg.solve(X.T @ X + lam * np.eye(d), X.T @ y)
    rng = np.random.default_rng(seed)
    w0_norm = np.sqrt(d)  # E||N(0, I_d)||
    return float(2.0 * (w0_norm + np.linalg.norm(w_star)))


def run(n_runs: int = 2):
    X, y, _ = make_regression_dataset(n=EP.n_samples, d=EP.n_features)
    N = EP.n_samples
    T = EP.T_factor * N
    D = _calibrate_D(X, y, EP.lam)
    consts = BoundConstants(L=EP.L, c=EP.c, M=EP.M, M_G=EP.M_G, D=D,
                            alpha=EP.alpha)

    t0 = time.perf_counter()
    out = {}
    for n_o in (10.0, 100.0, 1000.0):
        # experimental sweep (the "computationally burdensome" search)
        losses = {n_c: average_final_loss(X, y, n_c=n_c, n_o=n_o, T=T,
                                          n_runs=n_runs, alpha=EP.alpha,
                                          lam=EP.lam) for n_c in N_C_GRID}
        n_c_star = min(losses, key=losses.get)

        # bound-optimised block size on the paper's FINE grid (the bound
        # landscape is bimodal — the paper plots the full curve)
        plan = optimize_block_size(N=N, T=T, n_o=n_o, tau_p=EP.tau_p,
                                   consts=consts)
        n_c_tilde = plan.n_c
        loss_tilde = average_final_loss(X, y, n_c=n_c_tilde, n_o=n_o, T=T,
                                        n_runs=n_runs, alpha=EP.alpha,
                                        lam=EP.lam)
        gap_pct = 100.0 * (loss_tilde - losses[n_c_star]) / losses[n_c_star]
        out[n_o] = {"losses_by_n_c": losses, "n_c_star": n_c_star,
                    "n_c_tilde": n_c_tilde, "loss_at_tilde": loss_tilde,
                    "gap_pct": gap_pct}
    dt_us = (time.perf_counter() - t0) * 1e6

    # loss-vs-time traces for the two optima at n_o = 100 (Fig. 4 lines)
    mid = out[100.0]
    traces = {}
    for label, n_c in (("experimental_opt", mid["n_c_star"]),
                       ("bound_opt", mid["n_c_tilde"])):
        r = run_pipelined_sgd(X, y, n_c=n_c, n_o=100.0, T=T, alpha=EP.alpha,
                              lam=EP.lam, record_every=1024)
        traces[label] = {"n_c": n_c, "times": r.trace_times.tolist(),
                         "loss": r.loss_trace.tolist()}

    save_artifact("fig4_training", {"by_overhead": out, "D_calibrated": D,
                                    "traces": traces})
    gaps = " ".join(f"n_o={int(k)}:gap={v['gap_pct']:.1f}%"
                    f"(nc~={v['n_c_tilde']},nc*={v['n_c_star']})"
                    for k, v in out.items())
    emit("fig4_training", dt_us, gaps + " (paper: 3.8%)")
    return out


if __name__ == "__main__":
    run()
