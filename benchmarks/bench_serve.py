"""Always-on planning-service benchmark: warmup, zero-trace SLO, latency.

Exercises :class:`repro.serve.PlanningService` the way production would:

  1. **Warmup** — AOT-compile every configured (objective, grid mode,
     batch bucket) executable; the warmup trace count and wall time are
     reported.
  2. **Mixed stream** — a heterogeneous request stream drawing from
     EVERY registered link model, cycled through every served objective
     and both grid modes (plus a slice routed by the admission policy),
     pushed through the continuous micro-batcher from a producer thread.
  3. **Assertions** — the serving SLOs this PR introduces:

       * ZERO post-warmup jit traces, read from the UNIFIED metrics
         registry (the same series a Prometheus scrape sees — so the
         gate also validates the export path end to end);
       * per-request phase spans SUM EXACTLY (<= 1 µs) to the reported
         enqueue-to-plan latency, and the device-fenced solve fraction
         clears a sanity floor (the spans are attributing real compute,
         not noise);
       * enqueue-to-plan p99 under a generous bound (the flush deadline
         plus a worst-case solve; this is a smoke floor, not a perf
         target — CI boxes are noisy);
       * service throughput >= 0.5x the one-shot ``plan_server`` driver
         on the SAME stream — with span recording on, so this floor is
         also the <= 5% span-overhead budget's enforcement point;
       * plans BITWISE-identical to direct ``FleetPlanner.plan_many``
         calls (the service adds routing, never arithmetic).

  4. **Artifact** — ``BENCH_serve.json`` at the repo root (schema: one
     row per (objective, grid_mode, bucket) plus the headline latency /
     throughput numbers), the perf-trajectory artifact CI uploads.

Standalone:  PYTHONPATH=src python -m benchmarks.bench_serve
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import bench_stamp, emit, save_artifact
from repro.fleet import FleetPlanner, PlanCache
from repro.launch.plan_server import serve as oneshot_serve
from repro.serve import (ALL_MODELS, PlanningService, ServiceConfig,
                         synth_requests)

N_REQUESTS = 2048
GRID_SIZE = 64
BUCKETS = (64, 256)
FLUSH_INTERVAL = 0.01
OBJECTIVE_IDS = ("corollary1", "markov_arq")
N_MAX = 8192
#: generous p99 bound (seconds): the flush deadline + a worst-case padded
#: solve + scheduler noise on a shared CI box.  A healthy run sits far
#: below this; tripping it means batching stalled, not that a solve was
#: slow.
P99_CEILING_S = 2.0
#: continuous batching must stay in the same class as offline batching;
#: spans/histograms/metrics are ON during the measured stream, so this
#: floor also bounds the observability overhead (a >5% span-recording
#: tax would show up here long before it hit 50%)
THROUGHPUT_FLOOR = 0.5
#: the spans must attribute REAL device compute: over a whole stream the
#: fenced solve share of enqueue-to-plan latency cannot round to zero
SOLVE_FRACTION_FLOOR = 1e-3
#: phase intervals are cut from one monotonic clock: sums are exact up
#: to float addition error
PHASE_SUM_TOL_S = 1e-6

#: perf-trajectory artifact written at the repo root
BENCH_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_serve.json")


def _mixed_stream(service, requests, seed):
    """Submit every request: half cycled explicitly through every served
    (objective, grid mode) pair, half routed by the admission policy —
    returns (records, stream wall-clock seconds, first-submit to
    last-plan)."""
    rng = np.random.default_rng(seed)
    instances = list(service.objectives.items())
    modes = service.config.grid_modes
    futures = []
    t0 = time.perf_counter()
    for i, scenario in enumerate(requests):
        if rng.random() < 0.5:
            fut = service.submit(scenario)          # admission policy
        else:
            _, obj = instances[i % len(instances)]
            mode = modes[i % len(modes)]
            fut = service.submit(scenario, objective=obj, grid_mode=mode)
        futures.append(fut)
    records = [f.result(timeout=300) for f in futures]
    return records, time.perf_counter() - t0


def run():
    config = ServiceConfig(grid_size=GRID_SIZE, batch_buckets=BUCKETS,
                           flush_interval=FLUSH_INTERVAL,
                           objective_ids=OBJECTIVE_IDS, n_max=N_MAX)
    service = PlanningService(config)
    warm_traces = service.warmup()
    emit("serve_warmup", service.warmup_seconds * 1e6,
         f"traces={warm_traces} objectives={len(service.objectives)} "
         f"modes={len(config.grid_modes)} buckets={len(BUCKETS)}")

    # dup_frac=0: every request is a distinct device class.  A duplicate
    # stream would serve jittered repeats from the quantised cache, whose
    # records were solved for a NEIGHBOURING scenario — correct serving
    # semantics, but not bitwise-comparable against a fresh direct solve.
    requests = synth_requests(N_REQUESTS, seed=31, dup_frac=0.0,
                              n_classes=N_REQUESTS, models=ALL_MODELS,
                              n_max=N_MAX)
    with service:
        records, stream_s = _mixed_stream(service, requests, seed=32)
    stats = service.stats()
    service_pps = N_REQUESTS / stream_s

    # ---- zero post-warmup traces (the tentpole SLO) ------------------------
    # read through the unified metrics registry, not the raw counter: the
    # value a Prometheus scrape would see is the value the gate checks,
    # and taking the snapshot parses the full exposition (an export
    # regression fails here, not on a dashboard later)
    metrics = service.metrics_snapshot()
    post_traces = int(metrics["repro_serve_post_warmup_traces_total"][()])
    assert post_traces == stats.counters.get("post_warmup_traces", 0), (
        "metrics registry and raw counter disagree on post-warmup traces")
    assert post_traces == 0, (
        f"{post_traces} jit trace(s) after warmup — the bucketed AOT sweep "
        f"missed a shape the stream reached: {stats.buckets}")
    assert stats.n_planned == N_REQUESTS, (
        f"planned {stats.n_planned} of {N_REQUESTS} requests")

    # ---- span decomposition ------------------------------------------------
    spans = service.spans.snapshot()
    assert spans, "no request spans recorded"
    worst = max(abs(s.phase_sum - s.latency_s) for s in spans)
    assert worst <= PHASE_SUM_TOL_S, (
        f"phase spans do not sum to enqueue-to-plan latency "
        f"(max gap {worst * 1e6:.2f} µs > {PHASE_SUM_TOL_S * 1e6:.0f} µs) "
        "— a phase interval is missing or double-counted")
    phases = stats.phases
    assert phases["batch_wait"] > 0.0, (
        "zero cumulative batch-wait over a whole stream: spans are not "
        "measuring queueing")
    assert stats.solve_fraction >= SOLVE_FRACTION_FLOOR, (
        f"device-fenced solve fraction {stats.solve_fraction:.5f} is below "
        f"{SOLVE_FRACTION_FLOOR} — solve attribution lost the actual "
        "compute")

    # ---- latency SLO -------------------------------------------------------
    p99_s = stats.latency_p99_ms / 1e3
    assert p99_s < P99_CEILING_S, (
        f"enqueue-to-plan p99 {p99_s:.3f}s exceeds the generous "
        f"{P99_CEILING_S:.1f}s ceiling — continuous batching is stalling")

    # ---- bitwise parity vs direct plan_many --------------------------------
    # same planner configuration, fresh instance: the service must add
    # routing/batching/caching around the solver, never arithmetic
    direct_planner = FleetPlanner(grid_size=GRID_SIZE, shard=config.shard,
                                  pow2_refine_widths=True)
    rng = np.random.default_rng(33)
    sample = rng.choice(N_REQUESTS, size=64, replace=False)
    mismatches = []
    for i in sample:
        rec = records[i]
        obj = service.objectives[rec.objective]
        # re-solve alone (bucket pad 1): padding must not change answers
        direct = direct_planner.plan_many([requests[i]], service.consts,
                                          objective=obj)[0]
        if direct != rec:
            mismatches.append((int(i), rec, direct))
    # grid-mode of the service pick is unknown here for policy-routed
    # requests; dense vs refine argmin-match is already asserted by the
    # fleet bench, and plan_many defaults to the planner's dense mode —
    # re-check any mismatch under refine before declaring failure
    real_mismatches = []
    for i, rec, direct in mismatches:
        obj = service.objectives[rec.objective]
        refined = direct_planner.plan_many([requests[i]], service.consts,
                                           objective=obj,
                                           grid_mode="refine")[0]
        if refined != rec:
            real_mismatches.append((i, rec, direct, refined))
    assert not real_mismatches, (
        f"{len(real_mismatches)} service plan(s) differ from direct "
        f"plan_many under BOTH grid modes; first: {real_mismatches[0]}")

    # ---- throughput floor vs the one-shot driver ---------------------------
    oneshot_planner = FleetPlanner(grid_size=GRID_SIZE)
    instances = list(service.objectives.values())
    modes = list(config.grid_modes)
    objectives = [instances[i % len(instances)] for i in range(N_REQUESTS)]
    grid_modes = [modes[i % len(modes)] for i in range(N_REQUESTS)]
    oneshot = oneshot_serve(requests, planner=oneshot_planner,
                            consts=service.consts,
                            cache=PlanCache(maxsize=config.cache_size),
                            batch_size=config.max_batch,
                            objectives=objectives, grid_modes=grid_modes)
    ratio = service_pps / oneshot.plans_per_sec \
        if oneshot.plans_per_sec else float("inf")
    assert ratio >= THROUGHPUT_FLOOR, (
        f"service throughput {service_pps:,.0f} plans/s is "
        f"{ratio:.2f}x the one-shot driver's {oneshot.plans_per_sec:,.0f} "
        f"(floor {THROUGHPUT_FLOOR}x) — continuous batching is losing too "
        "much to queueing")

    emit("serve_stream", stream_s * 1e6,
         f"S={N_REQUESTS} {service_pps:,.0f}plans/s "
         f"p50={stats.latency_p50_ms:.1f}ms p99={stats.latency_p99_ms:.1f}ms "
         f"post_warm_traces={post_traces} vs_oneshot={ratio:.2f}x")
    means = service.spans.phase_means_ms()
    emit("serve_phases", means["latency"] * 1e3,
         f"batch_wait={means['batch_wait']:.2f}ms pad={means['pad']:.2f}ms "
         f"cache={means['cache_lookup']:.2f}ms "
         f"solve={means['solve']:.2f}ms resolve={means['resolve']:.2f}ms "
         f"solve_frac={stats.solve_fraction:.3f}")

    rows = [{"objective": oid, "grid_mode": mode, "bucket": bucket,
             "requests": slot["requests"], "batches": slot["batches"],
             "compiles": slot["compiles"]}
            for (oid, mode, bucket), slot in sorted(stats.buckets.items())]
    payload = {
        "bench": "serve",
        **bench_stamp(),
        "n_requests": N_REQUESTS, "grid_size": GRID_SIZE,
        "buckets": list(BUCKETS), "flush_interval_s": FLUSH_INTERVAL,
        "warmup_traces": warm_traces,
        "warmup_seconds": service.warmup_seconds,
        "post_warmup_traces": post_traces,
        "plans_per_sec": service_pps,
        "stream_seconds": stream_s,
        "latency_p50_ms": stats.latency_p50_ms,
        "latency_p99_ms": stats.latency_p99_ms,
        "latency_max_ms": stats.latency_max_ms,
        "phase_means_ms": means,
        "solve_fraction": stats.solve_fraction,
        "solve_device_seconds": phases.get("solve_device", 0.0),
        "oneshot_plans_per_sec": oneshot.plans_per_sec,
        "throughput_vs_oneshot": ratio,
        "cache": stats.cache,
        "rows": rows,
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    save_artifact("serve", payload)
    return stats, ratio


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
