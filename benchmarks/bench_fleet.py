"""Fleet planning engine benchmark: batched vs scalar-loop throughput.

Plans 4096 heterogeneous scenarios (per-scenario ``N``, deadline, overhead,
link model + params, device count; joint search over 5 candidate rates) two
ways.  By default the population MIXES every registered channel family
(ideal / erasure / fading / Gilbert-Elliott) in one ``ScenarioBatch``, so
the timed batched path includes the per-scenario ``jax.lax.switch`` link
dispatch; restrict with ``--models erasure`` etc. to benchmark one family:

  * scalar — the PR-1 :class:`BoundPlanner` in a Python loop, one scenario
    at a time (already fully vectorised over its own (rate, n_c) grid);
  * batched — ONE jitted ``FleetPlanner.plan_batch`` call over the whole
    :class:`ScenarioBatch`.

Both paths solve the IDENTICAL problem: same scenarios, same per-scenario
log-spaced grid (precomputed once, outside both timings).  The batched
time is the min over repeats (standard microbenchmark practice; the min
estimates the noise-free cost), the scalar loop is long enough (~1.5 s)
to be stable as a single pass.  Asserts the batched path is >= 50x faster
and that sampled batched plans match the scalar plans exactly (or are
within 1e-9 relative of the scalar optimum on argmin ties).

Also replays a realistic request stream (50% repeated device classes with
sub-quantisation jitter) through the micro-batching server to measure the
PlanCache hit-rate and cached serving throughput.

``--objective`` (default: all three registered objectives) additionally
times the batched ``markov_arq`` (exact burst-aware ARQ) solve on the same
population and the batched ``montecarlo`` (simulated empirical) solve on a
scaled-down one, emitting one plans/sec row per objective into the CSV
artifact; the >= 50x floor applies to the ``corollary1`` bound objective.
Unknown objective names exit with status 2 (like unknown bench names in
``benchmarks.run``).

``--grid-mode`` (default: both modes) additionally runs the coarse->fine
REFINEMENT comparison on fleet-scale tight-deadline populations: the
dense single-pass and the two-pass refined solve of the same grids are
timed per objective, the plans are asserted argmin-identical (up to the
documented parity floors — the Monte-Carlo landscape is seed-noise
ragged, so a small fraction of its refined plans land on a neighbouring
near-tie within ``MC_REFINE_GAP_CEIL``), and the refined path must beat
its dense path by >= 2x (``corollary1``) / >= 3x (``montecarlo``).  One
plans/sec CSV row is emitted per (objective, grid mode) and the whole
table is written to ``BENCH_fleet.json`` at the repo root (schema:
objective, grid_mode, S, plans_per_sec, speedup) as the perf-trajectory
artifact CI uploads.

The ``montecarlo`` comparison is followed by the FAST configuration
(common random numbers + the (32, 6) multi-level seed/stride schedule,
a 2048-slot coarse-pass horizon cap and a +/-10-step fine window; the
``refine_fast`` row): a HARD >= 10x plans/sec floor over the refined
scan baseline re-timed INTERLEAVED with the fast path in the same
process (single-core wall time drifts tens of percent between
processes, so only interleaved repeats give a stable ratio), plus
same-estimator argmin parity, an exact-reference objective-gap ceiling,
and zero retraces during the timed repeats.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import numpy as np

from benchmarks.common import bench_stamp, emit, save_artifact
from repro.core import BoundPlanner, MarkovARQObjective, ObjectivePlanner
from repro.core.planner import fleet_grid
from repro.core.scenario import MultiDevice, Scenario, SingleDevice
from repro.fleet import GRID_MODES, FleetPlanner, PlanCache, ScenarioBatch
from repro.fleet.tracing import trace_delta
from repro.launch.plan_server import (ALL_MODELS, ALL_OBJECTIVES,
                                      LINK_FACTORIES, _parse_models,
                                      default_consts, resolve_grid_modes,
                                      resolve_objectives, serve,
                                      synth_requests)

N_SCENARIOS = 4096
GRID_SIZE = 32
SPEEDUP_FLOOR = 50.0         # on a >= 4-core machine; see _speedup_floor()


def _speedup_floor() -> float:
    """The batched-vs-scalar floor this MACHINE should clear.

    The 50x baseline holds on >= 4 cores (where XLA's batched kernel
    gets its intra-op parallelism while the scalar loop stays serial);
    constrained CI containers (1-2 cores) have measured ~33x on
    unmodified code, so the floor scales down with ``os.cpu_count()``
    rather than failing the run for reasons unrelated to the diff.
    ``REPRO_BENCH_FLOOR_SCALE`` multiplies the result (0 disables the
    assert entirely) for machines the heuristic misjudges.
    """
    cores = os.cpu_count() or 1
    floor = SPEEDUP_FLOOR * min(1.0, cores / 4.0)
    scale = float(os.environ.get("REPRO_BENCH_FLOOR_SCALE", "1.0"))
    if scale < 0.0:
        raise ValueError(
            f"REPRO_BENCH_FLOOR_SCALE must be >= 0, got {scale}")
    return floor * scale
EQUIV_SAMPLE_STRIDE = 32     # scalar-check every 32nd scenario (128 total)
MC_SCENARIOS = 128           # the Monte-Carlo objective SIMULATES training
MC_GRID_SIZE = 8             # per plan, so its population is scaled down
MC_N_MAX = 2048

# ---- coarse->fine refinement comparison ------------------------------------
# Fleet-scale latency-constrained population (the paper's regime: deadline
# close to the transfer floor).  The dense reference width matches the
# density of the scalar planner's ~400-point default_grid; the two-pass
# solve evaluates ~ G/k + (2k+1) + guarded-tail lanes, cutting per-plan
# work ~3x for the closed-form bound (whose small-block-count sawtooth
# tail stays densely evaluated) and ~4x for Monte Carlo (pure bracket —
# every eliminated grid point is an eliminated training simulation, so
# its comparison runs at width 128 to bound the dense simulation cost).
REFINE_GRID_SIZE = 384
MC_REFINE_GRID_SIZE = 128
REFINE_SCENARIOS = 1024
REFINE_SPEEDUP_FLOOR = 2.0       # refined corollary1 vs its dense path
REFINE_PARITY_FLOOR = 0.99       # exact argmin parity fraction (corollary1)
REFINE_GAP_CEIL = 0.10           # worst residual objective gap (corollary1)
MC_REFINE_SCENARIOS = 16
MC_REFINE_SPEEDUP_FLOOR = 3.0    # refined montecarlo vs its dense path
MC_REFINE_PARITY_FLOOR = 0.5     # MC's landscape is seed-noise-ragged
MC_REFINE_GAP_CEIL = 0.05

# ---- Monte-Carlo at serving speed (CRN + seed/stride schedules) ------------
# The fast configuration attacks the corollary1-vs-montecarlo planning gap:
# the common-random-numbers estimator plus a (32, 6) multi-level stride
# schedule with a 1-seed / top-1-rate coarse budget evaluates ~62 simulated
# lane-runs per scenario instead of the dense 1280, and the coarse passes
# additionally train a TRUNCATED 2048-slot horizon (a bitwise prefix of
# the full timeline under CRN) — basin ranking survives the truncation,
# and the +/-10-step fine window (wider than the last stride's +/-6)
# repairs the residual center drift at full horizon / full seeds.  A HARD
# >= 10x plans/sec floor over the PR-5 refined scan baseline, re-timed
# interleaved with the fast path.  Quality gates: argmin parity against
# the dense solve of the SAME CRN estimator (the empirical landscape is
# seed-noise ragged, so ANY estimator change moves near-tie argmins —
# cross-estimator parity is not a meaningful gate) and the residual
# objective gap against the exact-stream dense reference.
MC_FAST_SCENARIOS = 64           # larger batch: fixed per-stage costs
MC_FAST_STRIDES = (32, 6)        # amortise across the batch
MC_FAST_FINE_RADIUS = 10         # dense fine window: +/-10 grid steps
MC_FAST_COARSE_UPDATES = 2048    # coarse-pass horizon cap (update slots)
MC_FAST_SPEEDUP_FLOOR = 10.0     # vs the refined scan baseline (PR 5)
MC_FAST_PARITY_FLOOR = 0.5       # vs the dense same-estimator solve
MC_FAST_GAP_CEIL = 0.05          # vs the exact-stream dense reference

#: perf-trajectory artifact written at the repo root (schema: one row per
#: (objective, grid_mode) with plans/sec and refined-vs-dense speedup)
BENCH_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_fleet.json")


def _fleet_population(n: int, seed: int):
    """Fleet-scale tight-deadline scenarios (N in [2^17, 2^20), T within
    5-40% of the dataset transfer floor) mixing every channel family."""
    rng = np.random.default_rng(seed)
    factories = list(LINK_FACTORIES.values())
    out = []
    for _ in range(n):
        N = int(rng.integers(1 << 17, 1 << 20))
        D = int(rng.choice([1, 1, 2, 4, 8]))
        out.append(Scenario(
            N=N, T=float(rng.uniform(1.05, 1.4)) * N,
            n_o=float(rng.uniform(10.0, 5000.0)),
            tau_p=float(rng.choice([0.5, 1.0, 2.0])),
            link=factories[int(rng.integers(len(factories)))](rng),
            topology=MultiDevice(D) if D > 1 else SingleDevice()))
    return out


def _mc_refine_population(n: int, seed: int):
    """Scaled-down tight-deadline population for the SIMULATED objective:
    tau_p = 2 and N < 11k bound the shared scan at 8192 update slots."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        N = int(rng.integers(4096, 11000))
        out.append(Scenario(
            N=N, T=float(rng.uniform(1.05, 1.4)) * N,
            n_o=float(rng.uniform(10.0, 2000.0)), tau_p=2.0,
            link=LINK_FACTORIES["erasure"](rng)))
    return out


def _bench_refine(objective_id, objective, scenarios, grid_size, consts,
                  repeats, *, speedup_floor, parity_floor, gap_ceil, rows):
    """Time one objective's dense vs coarse->fine solve on the same grids,
    assert plan parity + the refinement speedup floor, and append one row
    per grid mode to the artifact ``rows``."""
    batch = ScenarioBatch.from_scenarios(scenarios)
    grids = fleet_grid(batch.N, grid_size)
    S = len(batch)
    planners = {mode: FleetPlanner(grid_size=grid_size, grid_mode=mode)
                for mode in GRID_MODES}
    plans, times = {}, {}
    for mode, planner in planners.items():
        def solve(planner=planner):
            return planner.plan_batch(batch, consts, grid=grids,
                                      objective=objective)
        plans[mode] = solve()                       # compile + warm
        times[mode] = min(_timed(solve) for _ in range(repeats))
    dense, refined = plans["dense"], plans["refine"]
    exact = float(np.mean((dense.n_c == refined.n_c)
                          & (dense.rate == refined.rate)))
    gap = float(np.max(np.abs(refined.bound_value / dense.bound_value - 1)))
    speedup = times["dense"] / times["refine"]
    for mode in GRID_MODES:
        rows.append({"objective": objective_id, "grid_mode": mode,
                     "S": S, "plans_per_sec": S / times[mode],
                     "speedup": times["dense"] / times[mode]})
        emit(f"fleet_refine_{objective_id}_{mode}", times[mode] * 1e6,
             f"S={S} G={grid_size} {S / times[mode]:,.0f}plans/s "
             f"speedup={times['dense'] / times[mode]:.2f}x "
             f"parity={exact:.3f} maxgap={gap:.1e}")
    assert exact >= parity_floor, (
        f"refined {objective_id} plans diverge from dense: parity {exact:.3f}"
        f" < {parity_floor} over {S} scenarios")
    assert gap <= gap_ceil, (
        f"refined {objective_id} residual objective gap {gap:.2e} exceeds "
        f"{gap_ceil:.0e}")
    assert speedup >= speedup_floor, (
        f"refined {objective_id} only {speedup:.2f}x over its dense path "
        f"(want >= {speedup_floor:.0f}x at S={S}, G={grid_size})")
    return {"speedup": speedup, "plans": plans, "times": times,
            "batch": batch, "grids": grids}


def _bench_mc_fast(objective, ref, consts, repeats, rows):
    """Monte-Carlo at serving speed: CRN + the (32, 6) multi-level seed/
    stride schedule with a 2048-slot coarse horizon and a +/-10-step fine
    window, vs the refined scan baseline from ``ref`` (the ``montecarlo``
    ``_bench_refine`` section) RE-TIMED here interleaved with the fast
    path — single-core wall time drifts tens of percent over minutes, so
    only alternating repeats in one process yield a stable ratio.

    Asserts the >= 10x plans/sec floor, the same-estimator argmin parity
    floor on the baseline's fixed S=16 cases, the residual objective gap
    ceiling vs the exact-stream dense reference, and that the timed
    repeats retrace NOTHING (the serving posture: after the first call
    at a shape, planning is pure compiled execution)."""
    fast = dataclasses.replace(objective, crn=True, coarse_seeds=1,
                               refine_rates=1,
                               coarse_strides=MC_FAST_STRIDES,
                               fine_radius=MC_FAST_FINE_RADIUS,
                               coarse_updates=MC_FAST_COARSE_UPDATES)
    planner = FleetPlanner(grid_size=MC_REFINE_GRID_SIZE,
                           grid_mode="refine")
    # quality gates on the baseline's fixed cases
    batch16, grids16 = ref["batch"], ref["grids"]
    dense_crn = planner.plan_batch(
        batch16, consts, grid=grids16, grid_mode="dense",
        objective=dataclasses.replace(objective, crn=True))
    fast16 = planner.plan_batch(batch16, consts, grid=grids16,
                                objective=fast)
    parity = float(np.mean((fast16.n_c == dense_crn.n_c)
                           & (fast16.rate == dense_crn.rate)))
    exact_dense = ref["plans"]["dense"]
    gap = float(np.max(np.abs(fast16.bound_value /
                              exact_dense.bound_value - 1)))

    # throughput at S=64 — the same draw stream as the fixed cases (its
    # first 16 scenarios ARE the parity population)
    scenarios = _mc_refine_population(MC_FAST_SCENARIOS, seed=29)
    batch = ScenarioBatch.from_scenarios(scenarios)
    grids = fleet_grid(batch.N, MC_REFINE_GRID_SIZE)
    S = len(batch)

    def solve():
        return planner.plan_batch(batch, consts, grid=grids,
                                  objective=fast)

    def solve_baseline():
        return planner.plan_batch(batch16, consts, grid=grids16,
                                  objective=objective)

    solve()                                           # compile + warm
    solve_baseline()            # warm (compiled by the _bench_refine run)
    t_fast = t_base = float("inf")
    with trace_delta() as traces:
        for _ in range(repeats):
            t_base = min(t_base, _timed(solve_baseline))
            t_fast = min(t_fast, _timed(solve))
    baseline_pps = len(batch16) / t_base
    fast_pps = S / t_fast
    speedup = fast_pps / baseline_pps
    rows.append({"objective": "montecarlo", "grid_mode": "refine_fast",
                 "S": S, "plans_per_sec": fast_pps, "speedup": speedup})
    emit("fleet_refine_montecarlo_fast", t_fast * 1e6,
         f"S={S} G={MC_REFINE_GRID_SIZE} strides={MC_FAST_STRIDES} "
         f"hz={MC_FAST_COARSE_UPDATES} rf={MC_FAST_FINE_RADIUS} "
         f"{fast_pps:,.0f}plans/s speedup={speedup:.2f}x "
         f"parity={parity:.3f} maxgap={gap:.1e}")
    assert traces.total == 0, (
        f"fast montecarlo timed repeats retraced {traces.total} kernels "
        f"({traces.by_tag}) — the schedule's shapes are not stable")
    assert parity >= MC_FAST_PARITY_FLOOR, (
        f"fast montecarlo parity {parity:.3f} vs the dense CRN solve "
        f"< {MC_FAST_PARITY_FLOOR} over {len(batch16)} scenarios")
    assert gap <= MC_FAST_GAP_CEIL, (
        f"fast montecarlo residual objective gap {gap:.2e} vs the exact "
        f"dense reference exceeds {MC_FAST_GAP_CEIL:.0e}")
    assert speedup >= MC_FAST_SPEEDUP_FLOOR, (
        f"fast montecarlo only {speedup:.2f}x over the refined scan "
        f"baseline ({fast_pps:.1f} vs {baseline_pps:.1f} plans/s; want "
        f">= {MC_FAST_SPEEDUP_FLOOR:.0f}x)")
    return speedup


def _write_bench_json(rows):
    """Merge this run's rows into the repo-root artifact by
    (objective, grid_mode, S), so a partial invocation (e.g.
    ``--objective montecarlo``) refreshes its own rows without
    clobbering the rest of the trajectory."""
    merged = {}
    try:
        with open(BENCH_JSON) as f:
            for row in json.load(f).get("rows", []):
                merged[(row.get("objective"), row.get("grid_mode"),
                        row.get("S"))] = row
    except (OSError, ValueError):
        pass
    for row in rows:
        merged[(row["objective"], row["grid_mode"], row["S"])] = row
    with open(BENCH_JSON, "w") as f:
        json.dump({"bench": "fleet", **bench_stamp(),
                   "schema": ["objective", "grid_mode",
                              "S", "plans_per_sec",
                              "speedup"],
                   "rows": list(merged.values())}, f, indent=1)


def run(models=ALL_MODELS, objectives=ALL_OBJECTIVES, grid_modes=GRID_MODES):
    consts = default_consts()
    # accept a pre-resolved {id: instance} catalogue (instances key the
    # jitted kernel caches, so resolve once) or names/"all"
    catalogue = (objectives if isinstance(objectives, dict)
                 else resolve_objectives(objectives))
    grid_modes = resolve_grid_modes(grid_modes) \
        if not isinstance(grid_modes, (tuple, list)) else tuple(grid_modes)
    bench_rows = []
    objective_rows = {}
    speedup = stats = None

    # ---- coarse->fine refinement vs the dense single-pass ------------------
    # (needs both modes; emits one plans/sec row per (objective, mode) and
    # asserts exact plan parity + the per-objective refinement floors)
    if not set(GRID_MODES) <= set(grid_modes) and "refine" in grid_modes:
        print("note: the refine-vs-dense comparison needs BOTH grid modes; "
              "run with --grid-mode all (or dense,refine) — refined "
              "sections skipped", file=sys.stderr)
    if set(GRID_MODES) <= set(grid_modes):
        if "corollary1" in catalogue:
            _bench_refine(
                "corollary1", catalogue["corollary1"],
                _fleet_population(REFINE_SCENARIOS, seed=23),
                REFINE_GRID_SIZE, consts, repeats=11,
                speedup_floor=REFINE_SPEEDUP_FLOOR,
                parity_floor=REFINE_PARITY_FLOOR,
                gap_ceil=REFINE_GAP_CEIL, rows=bench_rows)
        if "montecarlo" in catalogue:
            mc_ref = _bench_refine(
                "montecarlo", catalogue["montecarlo"],
                _mc_refine_population(MC_REFINE_SCENARIOS, seed=29),
                MC_REFINE_GRID_SIZE, consts, repeats=2,
                speedup_floor=MC_REFINE_SPEEDUP_FLOOR,
                parity_floor=MC_REFINE_PARITY_FLOOR,
                gap_ceil=MC_REFINE_GAP_CEIL, rows=bench_rows)
            _bench_mc_fast(catalogue["montecarlo"], mc_ref, consts,
                           repeats=3, rows=bench_rows)
    # dup_frac=0 -> every request is a distinct device class (worst case
    # for the cache, the right population for a raw-throughput comparison)
    scenarios = synth_requests(N_SCENARIOS, seed=11, dup_frac=0.0,
                               n_classes=N_SCENARIOS, models=models)
    batch = ScenarioBatch.from_scenarios(scenarios)
    model_mix = sorted({int(m) for m in batch.link_model_id})
    grids = fleet_grid(batch.N, GRID_SIZE)      # shared data prep: (S, G)
    planner = FleetPlanner(grid_size=GRID_SIZE)

    if "markov_arq" in catalogue:
        markov = catalogue["markov_arq"]
        planner.plan_batch(batch, consts, grid=grids, objective=markov)
        t_markov = min(
            _timed(lambda: planner.plan_batch(batch, consts, grid=grids,
                                              objective=markov))
            for _ in range(7))
        objective_rows["markov_arq"] = N_SCENARIOS / t_markov
        bench_rows.append({"objective": "markov_arq", "grid_mode": "dense",
                           "S": N_SCENARIOS,
                           "plans_per_sec": N_SCENARIOS / t_markov,
                           "speedup": None})
        # exact burst-aware picks must match the scalar objective planner
        for i in range(0, N_SCENARIOS, N_SCENARIOS // 8):
            sp = ObjectivePlanner(objective=MarkovARQObjective(),
                                  grid=grids[i]).plan(scenarios[i], consts)
            fm = planner.plan_batch(
                ScenarioBatch.from_scenarios([scenarios[i]]), consts,
                grid=grids[i:i + 1], objective=markov)
            assert (int(fm.n_c[0]), float(fm.rate[0])) == (sp.n_c, sp.rate) \
                or abs(float(fm.bound_value[0]) - sp.bound_value) \
                <= 1e-9 * abs(sp.bound_value), (i, sp.n_c, int(fm.n_c[0]))
        emit("fleet_plan_batch_markov_arq", t_markov * 1e6,
             f"S={N_SCENARIOS} G={GRID_SIZE} "
             f"batched={N_SCENARIOS / t_markov:,.0f}plans/s")

    if "montecarlo" in catalogue:
        mc = catalogue["montecarlo"]
        mc_scenarios = synth_requests(MC_SCENARIOS, seed=13, dup_frac=0.0,
                                      n_classes=MC_SCENARIOS, models=models,
                                      n_max=MC_N_MAX)
        mc_batch = ScenarioBatch.from_scenarios(mc_scenarios)
        mc_grids = fleet_grid(mc_batch.N, MC_GRID_SIZE)
        mc_planner = FleetPlanner(grid_size=MC_GRID_SIZE)
        mc_planner.plan_batch(mc_batch, consts, grid=mc_grids, objective=mc)
        t_mc = min(
            _timed(lambda: mc_planner.plan_batch(mc_batch, consts,
                                                 grid=mc_grids,
                                                 objective=mc))
            for _ in range(3))
        objective_rows["montecarlo"] = MC_SCENARIOS / t_mc
        bench_rows.append({"objective": "montecarlo", "grid_mode": "dense",
                           "S": MC_SCENARIOS,
                           "plans_per_sec": MC_SCENARIOS / t_mc,
                           "speedup": None})
        emit("fleet_plan_batch_montecarlo", t_mc * 1e6,
             f"S={MC_SCENARIOS} G={MC_GRID_SIZE} n_runs={mc.n_runs} "
             f"batched={MC_SCENARIOS / t_mc:,.0f}plans/s (simulated)")

    if "corollary1" not in catalogue:
        save_artifact("fleet", {
            "n_scenarios": N_SCENARIOS, "grid_size": GRID_SIZE,
            "models": list(models), "model_ids_in_batch": model_mix,
            "objective_plans_per_sec": objective_rows,
        })
        _write_bench_json(bench_rows)
        return speedup, stats

    # ---- batched: one jitted call, min over repeats ------------------------
    fleet_plan = planner.plan_batch(batch, consts, grid=grids)  # compile+warm
    # 13 repeats (up from 7): the per-call cost is ~15 ms, and on a noisy
    # shared box the min needs more draws to reliably land near the
    # noise-free floor the assertion is calibrated against
    t_batched = min(
        _timed(lambda: planner.plan_batch(batch, consts, grid=grids))
        for _ in range(13))
    objective_rows["corollary1"] = N_SCENARIOS / t_batched
    bench_rows.append({"objective": "corollary1", "grid_mode": "dense",
                       "S": N_SCENARIOS,
                       "plans_per_sec": N_SCENARIOS / t_batched,
                       "speedup": None})

    # ---- scalar: the PR-1 planner in a Python loop -------------------------
    scalar_plans = []
    t0 = time.perf_counter()
    for i, sc in enumerate(scenarios):
        scalar_plans.append(BoundPlanner(grid=grids[i]).plan(sc, consts))
    t_scalar = time.perf_counter() - t0

    speedup = t_scalar / t_batched

    # ---- plan equivalence on a sample --------------------------------------
    exact = near = 0
    for i in range(0, N_SCENARIOS, EQUIV_SAMPLE_STRIDE):
        sp = scalar_plans[i]
        if sp.n_c == int(fleet_plan.n_c[i]) and sp.rate == float(fleet_plan.rate[i]):
            exact += 1
        else:
            near += 1
        gap = abs(sp.bound_value - float(fleet_plan.bound_value[i]))
        assert gap <= 1e-9 * abs(sp.bound_value), (
            f"scenario {i}: batched bound {float(fleet_plan.bound_value[i])} "
            f"vs scalar {sp.bound_value}")
    assert near == 0 or exact > near, (
        f"batched plans diverge from scalar: {exact} exact, {near} argmin ties")

    # ---- cached serving throughput on a realistic stream -------------------
    stream = synth_requests(N_SCENARIOS, seed=12, dup_frac=0.5,
                            models=models)
    cache = PlanCache(maxsize=8192)
    stats = serve(stream, planner=planner, consts=consts, cache=cache,
                  batch_size=256)

    save_artifact("fleet", {
        "n_scenarios": N_SCENARIOS, "grid_size": GRID_SIZE,
        "models": list(models), "model_ids_in_batch": model_mix,
        "objective_plans_per_sec": objective_rows,
        "batched_s": t_batched, "scalar_loop_s": t_scalar,
        "speedup": speedup,
        "batched_plans_per_sec": N_SCENARIOS / t_batched,
        "scalar_plans_per_sec": N_SCENARIOS / t_scalar,
        "equiv_sample": {"exact": exact, "argmin_ties": near},
        "served_plans_per_sec": stats.plans_per_sec,
        "cache_hit_rate": stats.cache_hit_rate,
    })
    _write_bench_json(bench_rows)
    emit("fleet_plan_batch", t_batched * 1e6,
         f"S={N_SCENARIOS} G={GRID_SIZE} models={len(model_mix)} "
         f"speedup={speedup:.0f}x "
         f"batched={N_SCENARIOS / t_batched:,.0f}plans/s "
         f"scalar={N_SCENARIOS / t_scalar:,.0f}plans/s "
         f"equiv={exact}/{exact + near}exact")
    emit("fleet_serve_cached", stats.seconds * 1e6,
         f"served={stats.n_requests} hit_rate={stats.cache_hit_rate:.2f} "
         f"{stats.plans_per_sec:,.0f}plans/s")

    if len(models) > 1:
        assert len(model_mix) > 1, (
            f"requested a mixed-model population {models} but the batch "
            f"only contains model ids {model_mix}")
    floor = _speedup_floor()
    assert speedup >= floor, (
        f"batched fleet planning (lax.switch over {len(model_mix)} link "
        f"model(s)) only {speedup:.1f}x faster than the scalar BoundPlanner "
        f"loop at {N_SCENARIOS} scenarios (want >= {floor:.0f}x on "
        f"{os.cpu_count() or 1} cores; REPRO_BENCH_FLOOR_SCALE overrides)")
    assert stats.cache_hit_rate >= 0.25, (
        f"PlanCache hit rate {stats.cache_hit_rate:.2f} on a 50%-duplicate "
        "stream — quantised keys are not collapsing repeated classes")
    return speedup, stats


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--models", default="all",
                    help="comma-separated link model mix, or 'all' "
                         f"({', '.join(ALL_MODELS)})")
    ap.add_argument("--objective", default="all",
                    help="comma-separated planning-objective mix, or 'all' "
                         f"({', '.join(ALL_OBJECTIVES)})")
    ap.add_argument("--grid-mode", default="all",
                    help="comma-separated grid-mode mix, or 'all' "
                         f"({', '.join(GRID_MODES)}); the refine-vs-dense "
                         "comparison sections need both modes")
    args = ap.parse_args()
    try:  # fail fast (exit 2, like an unknown bench name in benchmarks.run)
        catalogue = resolve_objectives(args.objective)
        modes = resolve_grid_modes(args.grid_mode)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        sys.exit(2)
    print("name,us_per_call,derived")
    run(models=_parse_models(args.models), objectives=catalogue,
        grid_modes=modes)
