"""Fleet planning engine benchmark: batched vs scalar-loop throughput.

Plans 4096 heterogeneous scenarios (per-scenario ``N``, deadline, overhead,
link model + params, device count; joint search over 5 candidate rates) two
ways.  By default the population MIXES every registered channel family
(ideal / erasure / fading / Gilbert-Elliott) in one ``ScenarioBatch``, so
the timed batched path includes the per-scenario ``jax.lax.switch`` link
dispatch; restrict with ``--models erasure`` etc. to benchmark one family:

  * scalar — the PR-1 :class:`BoundPlanner` in a Python loop, one scenario
    at a time (already fully vectorised over its own (rate, n_c) grid);
  * batched — ONE jitted ``FleetPlanner.plan_batch`` call over the whole
    :class:`ScenarioBatch`.

Both paths solve the IDENTICAL problem: same scenarios, same per-scenario
log-spaced grid (precomputed once, outside both timings).  The batched
time is the min over repeats (standard microbenchmark practice; the min
estimates the noise-free cost), the scalar loop is long enough (~1.5 s)
to be stable as a single pass.  Asserts the batched path is >= 50x faster
and that sampled batched plans match the scalar plans exactly (or are
within 1e-9 relative of the scalar optimum on argmin ties).

Also replays a realistic request stream (50% repeated device classes with
sub-quantisation jitter) through the micro-batching server to measure the
PlanCache hit-rate and cached serving throughput.
"""
from __future__ import annotations

import argparse
import time

from benchmarks.common import emit, save_artifact
from repro.core import BoundPlanner
from repro.core.planner import fleet_grid
from repro.fleet import FleetPlanner, PlanCache, ScenarioBatch
from repro.launch.plan_server import (ALL_MODELS, _parse_models,
                                      default_consts, serve, synth_requests)

N_SCENARIOS = 4096
GRID_SIZE = 32
SPEEDUP_FLOOR = 50.0
EQUIV_SAMPLE_STRIDE = 32     # scalar-check every 32nd scenario (128 total)


def run(models=ALL_MODELS):
    consts = default_consts()
    # dup_frac=0 -> every request is a distinct device class (worst case
    # for the cache, the right population for a raw-throughput comparison)
    scenarios = synth_requests(N_SCENARIOS, seed=11, dup_frac=0.0,
                               n_classes=N_SCENARIOS, models=models)
    batch = ScenarioBatch.from_scenarios(scenarios)
    model_mix = sorted({int(m) for m in batch.link_model_id})
    grids = fleet_grid(batch.N, GRID_SIZE)      # shared data prep: (S, G)

    # ---- batched: one jitted call, min over repeats ------------------------
    planner = FleetPlanner(grid_size=GRID_SIZE)
    fleet_plan = planner.plan_batch(batch, consts, grid=grids)  # compile+warm
    # 13 repeats (up from 7): the per-call cost is ~15 ms, and on a noisy
    # shared box the min needs more draws to reliably land near the
    # noise-free floor the assertion is calibrated against
    t_batched = min(
        _timed(lambda: planner.plan_batch(batch, consts, grid=grids))
        for _ in range(13))

    # ---- scalar: the PR-1 planner in a Python loop -------------------------
    scalar_plans = []
    t0 = time.perf_counter()
    for i, sc in enumerate(scenarios):
        scalar_plans.append(BoundPlanner(grid=grids[i]).plan(sc, consts))
    t_scalar = time.perf_counter() - t0

    speedup = t_scalar / t_batched

    # ---- plan equivalence on a sample --------------------------------------
    exact = near = 0
    for i in range(0, N_SCENARIOS, EQUIV_SAMPLE_STRIDE):
        sp = scalar_plans[i]
        if sp.n_c == int(fleet_plan.n_c[i]) and sp.rate == float(fleet_plan.rate[i]):
            exact += 1
        else:
            near += 1
        gap = abs(sp.bound_value - float(fleet_plan.bound_value[i]))
        assert gap <= 1e-9 * abs(sp.bound_value), (
            f"scenario {i}: batched bound {float(fleet_plan.bound_value[i])} "
            f"vs scalar {sp.bound_value}")
    assert near == 0 or exact > near, (
        f"batched plans diverge from scalar: {exact} exact, {near} argmin ties")

    # ---- cached serving throughput on a realistic stream -------------------
    stream = synth_requests(N_SCENARIOS, seed=12, dup_frac=0.5,
                            models=models)
    cache = PlanCache(maxsize=8192)
    stats = serve(stream, planner=planner, consts=consts, cache=cache,
                  batch_size=256)

    save_artifact("fleet", {
        "n_scenarios": N_SCENARIOS, "grid_size": GRID_SIZE,
        "models": list(models), "model_ids_in_batch": model_mix,
        "batched_s": t_batched, "scalar_loop_s": t_scalar,
        "speedup": speedup,
        "batched_plans_per_sec": N_SCENARIOS / t_batched,
        "scalar_plans_per_sec": N_SCENARIOS / t_scalar,
        "equiv_sample": {"exact": exact, "argmin_ties": near},
        "served_plans_per_sec": stats.plans_per_sec,
        "cache_hit_rate": stats.cache_hit_rate,
    })
    emit("fleet_plan_batch", t_batched * 1e6,
         f"S={N_SCENARIOS} G={GRID_SIZE} models={len(model_mix)} "
         f"speedup={speedup:.0f}x "
         f"batched={N_SCENARIOS / t_batched:,.0f}plans/s "
         f"scalar={N_SCENARIOS / t_scalar:,.0f}plans/s "
         f"equiv={exact}/{exact + near}exact")
    emit("fleet_serve_cached", stats.seconds * 1e6,
         f"served={stats.n_requests} hit_rate={stats.cache_hit_rate:.2f} "
         f"{stats.plans_per_sec:,.0f}plans/s")

    if len(models) > 1:
        assert len(model_mix) > 1, (
            f"requested a mixed-model population {models} but the batch "
            f"only contains model ids {model_mix}")
    assert speedup >= SPEEDUP_FLOOR, (
        f"batched fleet planning (lax.switch over {len(model_mix)} link "
        f"model(s)) only {speedup:.1f}x faster than the scalar BoundPlanner "
        f"loop at {N_SCENARIOS} scenarios (want >= {SPEEDUP_FLOOR:.0f}x)")
    assert stats.cache_hit_rate >= 0.25, (
        f"PlanCache hit rate {stats.cache_hit_rate:.2f} on a 50%-duplicate "
        "stream — quantised keys are not collapsing repeated classes")
    return speedup, stats


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--models", default="all",
                    help="comma-separated link model mix, or 'all' "
                         f"({', '.join(ALL_MODELS)})")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(models=_parse_models(args.models))
