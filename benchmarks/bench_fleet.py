"""Fleet planning engine benchmark: batched vs scalar-loop throughput.

Plans 4096 heterogeneous scenarios (per-scenario ``N``, deadline, overhead,
link model + params, device count; joint search over 5 candidate rates) two
ways.  By default the population MIXES every registered channel family
(ideal / erasure / fading / Gilbert-Elliott) in one ``ScenarioBatch``, so
the timed batched path includes the per-scenario ``jax.lax.switch`` link
dispatch; restrict with ``--models erasure`` etc. to benchmark one family:

  * scalar — the PR-1 :class:`BoundPlanner` in a Python loop, one scenario
    at a time (already fully vectorised over its own (rate, n_c) grid);
  * batched — ONE jitted ``FleetPlanner.plan_batch`` call over the whole
    :class:`ScenarioBatch`.

Both paths solve the IDENTICAL problem: same scenarios, same per-scenario
log-spaced grid (precomputed once, outside both timings).  The batched
time is the min over repeats (standard microbenchmark practice; the min
estimates the noise-free cost), the scalar loop is long enough (~1.5 s)
to be stable as a single pass.  Asserts the batched path is >= 50x faster
and that sampled batched plans match the scalar plans exactly (or are
within 1e-9 relative of the scalar optimum on argmin ties).

Also replays a realistic request stream (50% repeated device classes with
sub-quantisation jitter) through the micro-batching server to measure the
PlanCache hit-rate and cached serving throughput.

``--objective`` (default: all three registered objectives) additionally
times the batched ``markov_arq`` (exact burst-aware ARQ) solve on the same
population and the batched ``montecarlo`` (simulated empirical) solve on a
scaled-down one, emitting one plans/sec row per objective into the CSV
artifact; the >= 50x floor applies to the ``corollary1`` bound objective.
Unknown objective names exit with status 2 (like unknown bench names in
``benchmarks.run``).
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import emit, save_artifact
from repro.core import BoundPlanner, MarkovARQObjective, ObjectivePlanner
from repro.core.planner import fleet_grid
from repro.fleet import FleetPlanner, PlanCache, ScenarioBatch
from repro.launch.plan_server import (ALL_MODELS, ALL_OBJECTIVES,
                                      _parse_models, default_consts,
                                      resolve_objectives, serve,
                                      synth_requests)

N_SCENARIOS = 4096
GRID_SIZE = 32
SPEEDUP_FLOOR = 50.0
EQUIV_SAMPLE_STRIDE = 32     # scalar-check every 32nd scenario (128 total)
MC_SCENARIOS = 128           # the Monte-Carlo objective SIMULATES training
MC_GRID_SIZE = 8             # per plan, so its population is scaled down
MC_N_MAX = 2048


def run(models=ALL_MODELS, objectives=ALL_OBJECTIVES):
    consts = default_consts()
    # accept a pre-resolved {id: instance} catalogue (instances key the
    # jitted kernel caches, so resolve once) or names/"all"
    catalogue = (objectives if isinstance(objectives, dict)
                 else resolve_objectives(objectives))
    objective_rows = {}
    speedup = stats = None
    # dup_frac=0 -> every request is a distinct device class (worst case
    # for the cache, the right population for a raw-throughput comparison)
    scenarios = synth_requests(N_SCENARIOS, seed=11, dup_frac=0.0,
                               n_classes=N_SCENARIOS, models=models)
    batch = ScenarioBatch.from_scenarios(scenarios)
    model_mix = sorted({int(m) for m in batch.link_model_id})
    grids = fleet_grid(batch.N, GRID_SIZE)      # shared data prep: (S, G)
    planner = FleetPlanner(grid_size=GRID_SIZE)

    if "markov_arq" in catalogue:
        markov = catalogue["markov_arq"]
        planner.plan_batch(batch, consts, grid=grids, objective=markov)
        t_markov = min(
            _timed(lambda: planner.plan_batch(batch, consts, grid=grids,
                                              objective=markov))
            for _ in range(7))
        objective_rows["markov_arq"] = N_SCENARIOS / t_markov
        # exact burst-aware picks must match the scalar objective planner
        for i in range(0, N_SCENARIOS, N_SCENARIOS // 8):
            sp = ObjectivePlanner(objective=MarkovARQObjective(),
                                  grid=grids[i]).plan(scenarios[i], consts)
            fm = planner.plan_batch(
                ScenarioBatch.from_scenarios([scenarios[i]]), consts,
                grid=grids[i:i + 1], objective=markov)
            assert (int(fm.n_c[0]), float(fm.rate[0])) == (sp.n_c, sp.rate) \
                or abs(float(fm.bound_value[0]) - sp.bound_value) \
                <= 1e-9 * abs(sp.bound_value), (i, sp.n_c, int(fm.n_c[0]))
        emit("fleet_plan_batch_markov_arq", t_markov * 1e6,
             f"S={N_SCENARIOS} G={GRID_SIZE} "
             f"batched={N_SCENARIOS / t_markov:,.0f}plans/s")

    if "montecarlo" in catalogue:
        mc = catalogue["montecarlo"]
        mc_scenarios = synth_requests(MC_SCENARIOS, seed=13, dup_frac=0.0,
                                      n_classes=MC_SCENARIOS, models=models,
                                      n_max=MC_N_MAX)
        mc_batch = ScenarioBatch.from_scenarios(mc_scenarios)
        mc_grids = fleet_grid(mc_batch.N, MC_GRID_SIZE)
        mc_planner = FleetPlanner(grid_size=MC_GRID_SIZE)
        mc_planner.plan_batch(mc_batch, consts, grid=mc_grids, objective=mc)
        t_mc = min(
            _timed(lambda: mc_planner.plan_batch(mc_batch, consts,
                                                 grid=mc_grids,
                                                 objective=mc))
            for _ in range(3))
        objective_rows["montecarlo"] = MC_SCENARIOS / t_mc
        emit("fleet_plan_batch_montecarlo", t_mc * 1e6,
             f"S={MC_SCENARIOS} G={MC_GRID_SIZE} n_runs={mc.n_runs} "
             f"batched={MC_SCENARIOS / t_mc:,.0f}plans/s (simulated)")

    if "corollary1" not in catalogue:
        save_artifact("fleet", {
            "n_scenarios": N_SCENARIOS, "grid_size": GRID_SIZE,
            "models": list(models), "model_ids_in_batch": model_mix,
            "objective_plans_per_sec": objective_rows,
        })
        return speedup, stats

    # ---- batched: one jitted call, min over repeats ------------------------
    fleet_plan = planner.plan_batch(batch, consts, grid=grids)  # compile+warm
    # 13 repeats (up from 7): the per-call cost is ~15 ms, and on a noisy
    # shared box the min needs more draws to reliably land near the
    # noise-free floor the assertion is calibrated against
    t_batched = min(
        _timed(lambda: planner.plan_batch(batch, consts, grid=grids))
        for _ in range(13))
    objective_rows["corollary1"] = N_SCENARIOS / t_batched

    # ---- scalar: the PR-1 planner in a Python loop -------------------------
    scalar_plans = []
    t0 = time.perf_counter()
    for i, sc in enumerate(scenarios):
        scalar_plans.append(BoundPlanner(grid=grids[i]).plan(sc, consts))
    t_scalar = time.perf_counter() - t0

    speedup = t_scalar / t_batched

    # ---- plan equivalence on a sample --------------------------------------
    exact = near = 0
    for i in range(0, N_SCENARIOS, EQUIV_SAMPLE_STRIDE):
        sp = scalar_plans[i]
        if sp.n_c == int(fleet_plan.n_c[i]) and sp.rate == float(fleet_plan.rate[i]):
            exact += 1
        else:
            near += 1
        gap = abs(sp.bound_value - float(fleet_plan.bound_value[i]))
        assert gap <= 1e-9 * abs(sp.bound_value), (
            f"scenario {i}: batched bound {float(fleet_plan.bound_value[i])} "
            f"vs scalar {sp.bound_value}")
    assert near == 0 or exact > near, (
        f"batched plans diverge from scalar: {exact} exact, {near} argmin ties")

    # ---- cached serving throughput on a realistic stream -------------------
    stream = synth_requests(N_SCENARIOS, seed=12, dup_frac=0.5,
                            models=models)
    cache = PlanCache(maxsize=8192)
    stats = serve(stream, planner=planner, consts=consts, cache=cache,
                  batch_size=256)

    save_artifact("fleet", {
        "n_scenarios": N_SCENARIOS, "grid_size": GRID_SIZE,
        "models": list(models), "model_ids_in_batch": model_mix,
        "objective_plans_per_sec": objective_rows,
        "batched_s": t_batched, "scalar_loop_s": t_scalar,
        "speedup": speedup,
        "batched_plans_per_sec": N_SCENARIOS / t_batched,
        "scalar_plans_per_sec": N_SCENARIOS / t_scalar,
        "equiv_sample": {"exact": exact, "argmin_ties": near},
        "served_plans_per_sec": stats.plans_per_sec,
        "cache_hit_rate": stats.cache_hit_rate,
    })
    emit("fleet_plan_batch", t_batched * 1e6,
         f"S={N_SCENARIOS} G={GRID_SIZE} models={len(model_mix)} "
         f"speedup={speedup:.0f}x "
         f"batched={N_SCENARIOS / t_batched:,.0f}plans/s "
         f"scalar={N_SCENARIOS / t_scalar:,.0f}plans/s "
         f"equiv={exact}/{exact + near}exact")
    emit("fleet_serve_cached", stats.seconds * 1e6,
         f"served={stats.n_requests} hit_rate={stats.cache_hit_rate:.2f} "
         f"{stats.plans_per_sec:,.0f}plans/s")

    if len(models) > 1:
        assert len(model_mix) > 1, (
            f"requested a mixed-model population {models} but the batch "
            f"only contains model ids {model_mix}")
    assert speedup >= SPEEDUP_FLOOR, (
        f"batched fleet planning (lax.switch over {len(model_mix)} link "
        f"model(s)) only {speedup:.1f}x faster than the scalar BoundPlanner "
        f"loop at {N_SCENARIOS} scenarios (want >= {SPEEDUP_FLOOR:.0f}x)")
    assert stats.cache_hit_rate >= 0.25, (
        f"PlanCache hit rate {stats.cache_hit_rate:.2f} on a 50%-duplicate "
        "stream — quantised keys are not collapsing repeated classes")
    return speedup, stats


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--models", default="all",
                    help="comma-separated link model mix, or 'all' "
                         f"({', '.join(ALL_MODELS)})")
    ap.add_argument("--objective", default="all",
                    help="comma-separated planning-objective mix, or 'all' "
                         f"({', '.join(ALL_OBJECTIVES)})")
    args = ap.parse_args()
    try:  # fail fast (exit 2, like an unknown bench name in benchmarks.run)
        catalogue = resolve_objectives(args.objective)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        sys.exit(2)
    print("name,us_per_call,derived")
    run(models=_parse_models(args.models), objectives=catalogue)
