"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import json
import os
import subprocess
import time
from datetime import datetime, timezone

ARTIFACTS = os.path.join(os.path.dirname(__file__), "artifacts")

#: BENCH_*.json schema: 2 adds the bench_stamp() provenance fields
#: (schema_version, generated_utc, git_commit) so runs from different
#: commits can be lined up into one perf trajectory (make_report.py).
SCHEMA_VERSION = 2


def bench_stamp() -> dict:
    """Provenance stamp merged into every BENCH_*.json payload:
    schema version, UTC generation time, and the git commit (``None``
    outside a git checkout — artifacts must still be writable there)."""
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        commit = None
    return {
        "schema_version": SCHEMA_VERSION,
        "generated_utc": datetime.now(timezone.utc).isoformat(
            timespec="seconds"),
        "git_commit": commit,
    }


def emit(name: str, us_per_call: float, derived: str = ""):
    """The run.py CSV contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")


def save_artifact(name: str, payload: dict):
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(os.path.join(ARTIFACTS, name + ".json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


def timed(fn, *args, repeat: int = 3, **kw):
    fn(*args, **kw)  # compile / warm
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6  # microseconds
