"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import json
import os
import time

ARTIFACTS = os.path.join(os.path.dirname(__file__), "artifacts")


def emit(name: str, us_per_call: float, derived: str = ""):
    """The run.py CSV contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")


def save_artifact(name: str, payload: dict):
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(os.path.join(ARTIFACTS, name + ".json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


def timed(fn, *args, repeat: int = 3, **kw):
    fn(*args, **kw)  # compile / warm
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6  # microseconds
