"""Paper Fig. 3: Corollary-1 bound versus block size n_c for several packet
overheads n_o.  Reports the bound-optimal block size (the crosses), the
regime boundary T = B_d (n_c + n_o) (the dots), and the two qualitative
claims: n_c-tilde grows with n_o, and large overheads flip the optimum into
the partial-transfer regime."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_artifact
from repro.configs.edge_ridge import EDGE_RIDGE_PARAMS as EP
from repro.core import BoundConstants, BoundPlanner, Scenario

OVERHEADS = [10.0, 100.0, 1000.0, 5000.0]


def run():
    N = EP.n_samples
    T = EP.T_factor * N
    consts = BoundConstants(L=EP.L, c=EP.c, M=EP.M, M_G=EP.M_G, D=1.0,
                            alpha=EP.alpha)
    planner = BoundPlanner()
    rows = []
    t0 = time.perf_counter()
    for n_o in OVERHEADS:
        plan = planner.plan(Scenario(N=N, T=T, n_o=n_o, tau_p=EP.tau_p),
                            consts)
        rows.append({
            "n_o": n_o,
            "n_c_tilde": plan.n_c,
            "bound_at_opt": plan.bound_value,
            "regime_boundary_n_c": plan.boundary,
            "full_transfer_at_opt": plan.full_transfer,
            "grid": plan.grid.tolist(),
            "bound_grid": plan.bound_grid.tolist(),
        })
    dt_us = (time.perf_counter() - t0) * 1e6 / len(OVERHEADS)

    ncs = [r["n_c_tilde"] for r in rows]
    monotone = all(a <= b for a, b in zip(ncs, ncs[1:]))
    regime_flip = rows[0]["full_transfer_at_opt"] and not rows[-1]["full_transfer_at_opt"]
    save_artifact("fig3_bound_sweep", {"rows": [
        {k: v for k, v in r.items() if k not in ("grid", "bound_grid")}
        for r in rows], "monotone": monotone, "regime_flip": regime_flip})
    save_artifact("fig3_bound_curves", {"rows": rows})

    emit("fig3_bound_sweep", dt_us,
         f"nc_tilde={ncs} monotone_in_overhead={monotone} "
         f"regime_flip={regime_flip}")
    assert monotone and regime_flip, "paper Fig.3 trends not reproduced"
    return rows


if __name__ == "__main__":
    run()
