"""Compute-layer micro-benchmarks on CPU wall-clock: the XLA blockwise
(flash-style) attention versus the naive full-logit attention, and the
scanned SSD versus the sequential recurrence.  (Pallas kernels are validated
in interpret mode — their perf story is the TPU roofline, not CPU time.)"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.models.attention import causal_mask, dot_product_attention
from repro.models.blockwise import flash_attention
from repro.models.mamba2 import ssd_chunked, ssd_reference


def run():
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    B, S, H, Hkv, D = 1, 1024, 8, 4, 64
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    pos = jnp.arange(S)

    f_block = jax.jit(lambda q, k, v: flash_attention(q, k, v, causal=True))
    f_naive = jax.jit(lambda q, k, v: dot_product_attention(
        q, k, v, mask=causal_mask(pos, pos)[None, None, None]))
    _, t_block = timed(lambda: f_block(q, k, v).block_until_ready())
    _, t_naive = timed(lambda: f_naive(q, k, v).block_until_ready())
    emit("attention_blockwise_1k", t_block, f"naive={t_naive:.0f}us")

    b, l, h, p, g, n = 1, 2048, 8, 64, 1, 64
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bm = jax.random.normal(ks[3], (b, l, g, n))
    cm = jax.random.normal(ks[4], (b, l, g, n))
    f_chunk = jax.jit(lambda *t: ssd_chunked(*t, chunk=256)[0])
    f_seq = jax.jit(lambda *t: ssd_reference(*t)[0])
    bm_h = jnp.repeat(bm, h // g, 2)
    cm_h = jnp.repeat(cm, h // g, 2)
    _, t_chunk = timed(lambda: f_chunk(x, dt, a, bm, cm).block_until_ready())
    _, t_seq = timed(lambda: f_seq(x, dt, a, bm_h, cm_h).block_until_ready())
    emit("ssd_chunked_2k", t_chunk,
         f"sequential={t_seq:.0f}us speedup={t_seq/t_chunk:.1f}x")


if __name__ == "__main__":
    run()
