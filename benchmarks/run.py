"""Benchmark harness — one entry per paper table/figure plus the roofline
report.  Prints ``name,us_per_call,derived`` CSV (the repo contract).

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run fig3 fig4  # subset
"""
from __future__ import annotations

import sys
import traceback

BENCHES = {
    # paper artefacts
    "fig3": ("benchmarks.bench_bound_sweep", "Fig. 3 bound-vs-block-size sweep"),
    "fig4": ("benchmarks.bench_training", "Fig. 4 training curves + 3.8% claim"),
    "pipeline": ("benchmarks.bench_pipeline_vs_sequential",
                 "pipelined vs sequential (motivating claim)"),
    # framework layers
    "kernels": ("benchmarks.bench_kernels", "compute-layer micro-bench"),
    "streaming_llm": ("benchmarks.bench_streaming_llm",
                      "beyond-paper: schedule on LLM pretraining"),
    "extensions": ("benchmarks.bench_extensions",
                   "paper Sec.-6 extensions: Th1 MC, noisy channel, multi-device"),
    "fleet": ("benchmarks.bench_fleet",
              "fleet engine: batched vs scalar-loop planning + cache hit-rate"),
    "serve": ("benchmarks.bench_serve",
              "always-on planning service: warmup, zero-trace SLO, latency"),
    "federated": ("benchmarks.bench_federated",
                  "federated round planner: joint selection + (rate, n_c)"),
    # roofline (reads dry-run artifacts)
    "roofline": ("benchmarks.roofline_report", "roofline aggregation"),
}


def main(argv=None) -> int:
    """Run the selected benchmarks; return a non-zero exit code on ANY
    failure (unknown name or raising bench) so CI can gate on it."""
    wanted = list(argv if argv is not None else sys.argv[1:]) or list(BENCHES)
    unknown = [k for k in wanted if k not in BENCHES]
    if unknown:
        print(f"unknown benchmark(s): {unknown}; "
              f"available: {sorted(BENCHES)}", file=sys.stderr)
        return 2
    print("name,us_per_call,derived")
    failures = []
    for key in wanted:
        mod_name, _desc = BENCHES[key]
        try:
            mod = __import__(mod_name, fromlist=["run"])
            mod.run()
        except Exception:
            failures.append(key)
            traceback.print_exc()
    if failures:
        print(f"benchmark failures: {failures}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
