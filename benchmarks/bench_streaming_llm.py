"""Beyond-paper experiment: the streaming-block schedule applied to LLM
pretraining (reduced llama config) — does the paper's bound-driven block
size also help when the 'sample' is a packed token sequence and the learner
is a transformer?  Mirrors the paper's metric: FINAL LOSS OVER THE FULL
DATASET after the deadline, under three schedules with the same deadline
T = 1.5 N: bound-optimised n_c, tiny blocks (overhead-dominated), and
sequential transmit-all-first (n_c = N)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_artifact
from repro.configs import get_config, reduced
from repro.core import BlockSchedule, BoundConstants, optimize_block_size
from repro.core.stream_trainer import run_streaming_training
from repro.data.synthetic import SyntheticTokens
from repro.models import init_params, make_train_step
from repro.models.transformer import loss_fn
from repro.optim.optimizers import make_optimizer


def _train_and_eval(cfg, params0, data, n_c, n_o, T, batch, eval_fn, seed=0):
    plan = BlockSchedule(N=len(data), n_c=n_c, n_o=n_o, T=T, tau_p=1.0)
    opt = make_optimizer("adamw", 1e-3)
    step = jax.jit(make_train_step(cfg, opt), donate_argnums=(0, 1))
    params = jax.tree.map(jnp.copy, params0)
    state = run_streaming_training(
        train_step=step, params=params, opt_state=opt.init(params),
        dataset=data, plan=plan, batch_size=batch, seed=seed, log_every=50)
    # paper metric: loss over the FULL dataset at the deadline
    return float(eval_fn(state.params)), state.delivered, state.step


def run(n_o: float = 16.0):
    cfg = reduced(get_config("llama3.2-1b"))
    n_seqs, seq, batch = 256, 64, 16
    data = SyntheticTokens(cfg.vocab_size, seq, n_seqs, 0).batch(0)
    params0 = init_params(cfg, 0)
    T = 1.5 * n_seqs

    eval_batches = [jnp.asarray(data[i:i + 32]) for i in range(0, n_seqs, 32)]
    eval_jit = jax.jit(lambda p, t: loss_fn(p, {"tokens": t}, cfg))

    def eval_fn(params):
        return np.mean([float(eval_jit(params, t)) for t in eval_batches])

    consts = BoundConstants(L=1.0, c=0.05, M=1.0, M_G=1.0, D=2.0, alpha=1e-3)
    plan = optimize_block_size(N=n_seqs, T=T, n_o=n_o, tau_p=1.0, consts=consts)

    t0 = time.perf_counter()
    results = {}
    for label, n_c in ((f"bound_opt_nc={plan.n_c}", plan.n_c),
                       ("tiny_blocks_nc=2", 2),
                       (f"sequential_nc={n_seqs}", n_seqs)):
        full_loss, delivered, steps = _train_and_eval(
            cfg, params0, data, n_c, n_o, T, batch, eval_fn)
        results[label] = {"full_data_loss": full_loss,
                          "delivered": delivered, "updates_run": steps}
    dt_us = (time.perf_counter() - t0) * 1e6 / 3
    save_artifact("streaming_llm", {"n_o": n_o, "T": T,
                                    "n_c_tilde": plan.n_c, "results": results})
    best = min(results, key=lambda k: results[k]["full_data_loss"])
    emit("streaming_llm_pretrain", dt_us,
         " ".join(f"{k}:{v['full_data_loss']:.3f}" for k, v in results.items())
         + f" best={best}")
    return results


if __name__ == "__main__":
    run()
