"""Inject the generated roofline tables into EXPERIMENTS.md placeholders."""
import re
import sys

from benchmarks.roofline_report import table

MARKERS = {
    "<!-- ROOFLINE_BASELINE_SP -->": ("pod16x16", ""),
    "<!-- ROOFLINE_OPT_SP -->": ("pod16x16", "__opt"),
}


def main(path="EXPERIMENTS.md"):
    src = open(path).read()
    for marker, (mesh, suffix) in MARKERS.items():
        t = table(mesh, suffix)
        block = f"{marker}\n{t}\n<!-- /generated -->"
        # replace marker (+ any previously generated block)
        pat = re.escape(marker) + r"(?:\n.*?<!-- /generated -->)?"
        src = re.sub(pat, block, src, flags=re.S)
    open(path, "w").write(src)
    print("EXPERIMENTS.md tables refreshed")


if __name__ == "__main__":
    main(*sys.argv[1:])
