"""Benchmark report generation.

Two subcommands:

  * ``roofline`` (default, for backward compatibility) — inject the
    generated roofline tables into ``EXPERIMENTS.md`` placeholders.
  * ``trajectory`` — merge the repo-root ``BENCH_fleet.json``,
    ``BENCH_serve.json`` and ``BENCH_federated.json`` perf artifacts
    (schema v2: stamped with
    ``schema_version`` / ``generated_utc`` / ``git_commit`` by
    ``benchmarks.common.bench_stamp``) into ONE markdown table, so two
    runs' artifacts can be diffed commit-to-commit as a trajectory:

      PYTHONPATH=src python -m benchmarks.make_report trajectory
      PYTHONPATH=src python -m benchmarks.make_report trajectory out.md
"""
import json
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MARKERS = {
    "<!-- ROOFLINE_BASELINE_SP -->": ("pod16x16", ""),
    "<!-- ROOFLINE_OPT_SP -->": ("pod16x16", "__opt"),
}


def roofline(path="EXPERIMENTS.md"):
    from benchmarks.roofline_report import table
    src = open(path).read()
    for marker, (mesh, suffix) in MARKERS.items():
        t = table(mesh, suffix)
        block = f"{marker}\n{t}\n<!-- /generated -->"
        # replace marker (+ any previously generated block)
        pat = re.escape(marker) + r"(?:\n.*?<!-- /generated -->)?"
        src = re.sub(pat, block, src, flags=re.S)
    open(path, "w").write(src)
    print("EXPERIMENTS.md tables refreshed")


def _load(name):
    try:
        with open(os.path.join(ROOT, name)) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def _fmt(v, spec=",.0f"):
    return format(v, spec) if isinstance(v, (int, float)) else "—"


def trajectory_table():
    """One merged markdown table over both perf artifacts.  Tolerates
    either artifact being absent (a partial bench run still reports) and
    pre-v2 payloads without the provenance stamp."""
    fleet, serve = _load("BENCH_fleet.json"), _load("BENCH_serve.json")
    federated = _load("BENCH_federated.json")
    lines = ["# Benchmark trajectory", ""]
    for name, payload in (("BENCH_fleet.json", fleet),
                          ("BENCH_serve.json", serve),
                          ("BENCH_federated.json", federated)):
        if payload is None:
            lines.append(f"_{name}: absent (run its bench to generate)_")
            lines.append("")
            continue
        commit = payload.get("git_commit") or "unknown"
        lines.append(
            f"_{name}: schema v{payload.get('schema_version', 1)}, "
            f"generated {payload.get('generated_utc', 'unknown')}, "
            f"commit `{str(commit)[:12]}`_")
        lines.append("")
    lines += ["| bench | objective | grid mode | metric | value |",
              "|---|---|---|---|---|"]
    if fleet:
        for row in sorted(fleet.get("rows", []),
                          key=lambda r: (str(r.get("objective")),
                                         str(r.get("grid_mode")))):
            lines.append(
                f"| fleet | {row.get('objective')} | {row.get('grid_mode')}"
                f" | plans/sec (S={row.get('S')}) "
                f"| {_fmt(row.get('plans_per_sec'))} |")
            if row.get("speedup") is not None:
                lines.append(
                    f"| fleet | {row.get('objective')} "
                    f"| {row.get('grid_mode')} | refine speedup "
                    f"| {_fmt(row.get('speedup'), '.2f')}x |")
    if serve:
        headline = [
            ("plans/sec", _fmt(serve.get("plans_per_sec"))),
            ("latency p50 ms", _fmt(serve.get("latency_p50_ms"), ".2f")),
            ("latency p99 ms", _fmt(serve.get("latency_p99_ms"), ".2f")),
            ("solve fraction", _fmt(serve.get("solve_fraction"), ".3f")),
            ("post-warmup traces", _fmt(serve.get("post_warmup_traces"))),
            ("vs one-shot", _fmt(serve.get("throughput_vs_oneshot"),
                                 ".2f")),
        ]
        for metric, value in headline:
            lines.append(f"| serve | mixed | mixed | {metric} | {value} |")
        for phase, ms in sorted(
                (serve.get("phase_means_ms") or {}).items()):
            lines.append(f"| serve | mixed | mixed | phase mean ms: "
                         f"{phase} | {_fmt(ms, '.3f')} |")
    if federated:
        S = federated.get("population")
        for metric, value in [
                (f"rounds/sec (S={S})",
                 _fmt(federated.get("rounds_per_sec"), ".1f")),
                (f"devices/sec (S={S})",
                 _fmt(federated.get("devices_per_sec"))),
                ("speedup vs scalar loop",
                 _fmt(federated.get("speedup_vs_scalar"), ".1f")),
                ("post-warmup traces",
                 _fmt(federated.get("post_warmup_traces"))),
        ]:
            lines.append(
                f"| federated | federated_corollary1 | dense "
                f"| {metric} | {value} |")
    return "\n".join(lines) + "\n"


def trajectory(out=None):
    text = trajectory_table()
    if out:
        open(out, "w").write(text)
        print(f"trajectory table written to {out}")
    else:
        print(text, end="")


def main(argv):
    if argv and argv[0] == "trajectory":
        trajectory(*argv[1:2])
    elif argv and argv[0] == "roofline":
        roofline(*argv[1:2])
    else:
        roofline(*argv[:1])


if __name__ == "__main__":
    main(sys.argv[1:])
