"""Aggregate the dry-run artifacts into the roofline table
(EXPERIMENTS.md §Roofline): per (arch x shape x mesh) the three terms,
the dominant bottleneck, MODEL_FLOPS/HLO_FLOPS, and a one-line lever."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import ARTIFACTS, emit

LEVERS = {
    ("memory", "train"): "flash-attention custom VJP removes the O(S^2) "
                         "backward residual traffic",
    ("memory", "prefill"): "fused flash kernel keeps tiles in VMEM "
                           "(one HBM pass over KV)",
    ("memory", "decode"): "KV-cache is the floor: quantise cache to int8 / "
                          "shard heads wider",
    ("collective", "train"): "shard-aware layout: avoid row-parallel "
                             "fallback allreduces; overlap grad reduce",
    ("collective", "prefill"): "reorder TP collectives; all-gather KV once "
                               "per layer instead of per block",
    ("collective", "decode"): "decode is latency-bound: fuse the per-layer "
                              "allreduce pair into one",
    ("compute", "train"): "block-skip causal tiles (Pallas) to cut masked "
                          "FLOPs; MXU-align tile shapes",
    ("compute", "prefill"): "causal block skipping halves attention FLOPs",
    ("compute", "decode"): "compute floor reached: batch requests wider",
}


def load_records(mesh: str = None, suffix: str = ""):
    """suffix='' -> baseline records only; suffix='__opt' -> that variant."""
    recs = []
    for p in sorted(glob.glob(os.path.join(ARTIFACTS, "*__*.json"))):
        with open(p) as f:
            r = json.load(f)
        tag = r.get("tag", "")
        if mesh and not tag.endswith(f"__{mesh}{suffix}"):
            continue
        recs.append(r)
    return recs


def table(mesh: str = "pod16x16", suffix: str = "") -> str:
    rows = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "GiB/dev | useful FLOPs | lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    kind_of = {"train_4k": "train", "prefill_32k": "prefill",
               "decode_32k": "decode", "long_500k": "decode"}
    for r in load_records(mesh, suffix):
        if r["status"] == "SKIP":
            rows.append(f"| {r['tag'].split('__')[0]} | "
                        f"{r['tag'].split('__')[1]} | — | — | — | SKIP | — | — "
                        f"| {r['reason'][:60]} |")
            continue
        ro = r["roofline"]
        lever = LEVERS.get((ro["dominant"], kind_of[r["shape"]]), "")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {ro['compute_s']:.3f} | "
            f"{ro['memory_s']:.3f} | {ro['collective_s']:.3f} | "
            f"**{ro['dominant']}** | {r['per_device_bytes']/2**30:.1f} | "
            f"{ro['useful_flops_ratio']*100:.0f}% | {lever} |")
    return "\n".join(rows)


def run():
    recs = load_records("pod16x16")
    ok = [r for r in recs if r["status"] == "OK"]
    skip = [r for r in recs if r["status"] == "SKIP"]
    emit("roofline_report", 0.0,
         f"single-pod pairs: {len(ok)} OK / {len(skip)} SKIP "
         f"(see EXPERIMENTS.md §Roofline)")
    mp = load_records("pod2x16x16")
    if mp:
        ok_mp = [r for r in mp if r["status"] == "OK"]
        emit("roofline_report_multipod", 0.0,
             f"multi-pod pairs: {len(ok_mp)} OK / "
             f"{len([r for r in mp if r['status'] == 'SKIP'])} SKIP")


if __name__ == "__main__":
    print(table())
