"""The paper's motivating comparison (Secs. 1, 5): pipelined block streaming
with an optimised block size versus transmitting the entire dataset first
(n_c = N: one block, one overhead, training only starts after the full
transfer)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_artifact
from repro.configs.edge_ridge import EDGE_RIDGE_PARAMS as EP
from repro.core import (BoundConstants, BoundPlanner, RidgeTask, Scenario,
                        Simulator)
from repro.data.synthetic import make_regression_dataset


def run(n_o: float = 500.0):
    X, y, _ = make_regression_dataset(n=EP.n_samples, d=EP.n_features)
    N, T = EP.n_samples, EP.T_factor * EP.n_samples
    consts = BoundConstants(L=EP.L, c=EP.c, M=EP.M, M_G=EP.M_G, D=6.0,
                            alpha=EP.alpha)
    scenario = Scenario(N=N, T=T, n_o=n_o, tau_p=EP.tau_p)
    plan = BoundPlanner().plan(scenario, consts)
    # n_c = N recovers the transmit-everything-first baseline
    seq_plan = BoundPlanner(grid=[N]).plan(scenario, consts)

    sim = Simulator()
    task = RidgeTask(X=X, y=y, alpha=EP.alpha, lam=EP.lam)
    t0 = time.perf_counter()
    piped = sim.run(scenario, plan, task)
    seq = sim.run(scenario, seq_plan, task)
    dt_us = (time.perf_counter() - t0) * 1e6 / 2

    improvement = (seq.final_loss - piped.final_loss) / seq.final_loss * 100.0
    save_artifact("pipeline_vs_sequential", {
        "n_o": n_o, "n_c_tilde": plan.n_c,
        "pipelined_final_loss": piped.final_loss,
        "sequential_final_loss": seq.final_loss,
        "improvement_pct": improvement,
    })
    emit("pipeline_vs_sequential", dt_us,
         f"pipelined={piped.final_loss:.4f} sequential={seq.final_loss:.4f} "
         f"improvement={improvement:.1f}%")
    assert piped.final_loss < seq.final_loss, \
        "pipelining must beat sequential (paper's motivating claim)"
    return piped, seq


if __name__ == "__main__":
    run()
